//! The discrete-event workload engine: processes a workload through the
//! *real* RMS state machine in virtual time, with modeled iteration and
//! reconfiguration costs (see [`super::sched_cost`], [`super::execmodel`]).
//!
//! The same `Rms` code drives both this engine and the live threaded mode
//! — the DES only replaces wall-clock execution with the calibrated model,
//! which is what lets the paper's 9-hour, 400-job workloads run in
//! milliseconds (DESIGN.md §2).
//!
//! ## Shards
//!
//! The engine is generalized over a vector of **shards**: each shard owns
//! its own `Rms` (cluster, priorities, availability profile), its own
//! cost/fault RNG streams (salted by shard id; shard 0's salt is zero)
//! and its own fault timeline, while the event heap, virtual clock and
//! action statistics stay global.  [`Engine::new`] builds the 1-shard
//! (flat) engine the paper's experiments use — every heterogeneity knob
//! then multiplies by exactly `1.0`, so the flat path is bit-identical
//! to pre-federation builds.  [`crate::federation::FedEngine`] builds the
//! multi-shard configuration with routing and work stealing.
//!
//! ## Complexity budget
//!
//! One simulated event costs O(active jobs), independent of how many jobs
//! have already completed:
//!
//! * Per-job simulation state lives in a **dense slab** (`Vec<SimJob>`
//!   plus an id→slot table) instead of a hash map; a `SimJob` carries a
//!   copyable [`SimSpec`] extracted from the `JobSpec` — starting a job
//!   allocates no strings and never clones the spec.  Completed jobs'
//!   slots go on a free list and are reused, so the slab's live size is
//!   bounded by the *active* job count, not the total processed
//!   (requeued/rescued jobs keep their slot — the checkpointed progress
//!   lives there).
//! * `iter_time` is memoized per (job, procs): the `powf` in the
//!   execution model is recomputed only when a resize changes the
//!   process count.
//! * Arrivals are **pulled lazily** from a [`JobStream`]: at most
//!   `window` unarrived jobs are resident (a small look-ahead instead of
//!   seeding every arrival up front).  Arrival events carry their pull
//!   ordinal as the heap tiebreaker (below [`ARRIVAL_FLOOR`]), so pop
//!   order — and therefore the whole event stream — is independent of
//!   the window size; `Engine::run` is the special case of a
//!   [`Materialized`] stream with an infinite window.
//! * Every state transition the engine drives — start, finish, resize
//!   commit, failure eviction, rescue shrink, requeue, expected-end
//!   refresh — goes through an `Rms` method that publishes the matching
//!   O(log active) delta to the incremental availability profile
//!   ([`crate::rms::profile`]), so scheduling passes never rebuild a
//!   running-jobs snapshot and provably no-op passes/checks are elided
//!   (`Rms::pass_stats` counts both).
//! * Federated runs add O(shards) per event (down-node integration and
//!   the steal scan) — shard counts are small constants.
//!
//! `RunResult::events` counts every processed event so throughput
//! benchmarks (`benches/hotpath_scale.rs`) can report events/s.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::execmodel::ExecModel;
use super::sched_cost::CostModel;
use crate::cluster::NodeState;
use crate::dmr::{Inhibitor, SchedMode};
use crate::obs::{Phase, PhaseProfile};
use crate::federation::{FedRunResult, FederationConfig, RoutingPolicy, ShardRun, StealPolicy};
use crate::resilience::{
    feasible_shrink, resize, FaultKind, FaultSpec, OutageSpec, ResilienceConfig,
    ResilienceStats, ResizeFaultSpec,
};
use crate::rms::{Action, DmrOutcome, DmrRequest, PolicyStrategy, Rms, RmsConfig};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::{fit_spec, JobSpec, JobStream, Materialized, WorkloadSpec};
use crate::{JobId, NodeId, Time};

/// DES configuration.
#[derive(Debug, Clone)]
pub struct DesConfig {
    /// Workload-manager configuration (cluster size, policy strategy…).
    pub rms: RmsConfig,
    /// Synchronous or asynchronous DMR scheduling (§5.1).
    pub mode: SchedMode,
    /// Reconfiguration cost model (Table 2 calibration).
    pub costs: CostModel,
    /// Iteration-time execution model (Table 1 calibration).
    pub exec: ExecModel,
    /// Seed of the cost-jitter RNG (and, via the runner, the workload).
    pub seed: u64,
    /// Fault injection + recovery (default: inactive — the event stream is
    /// then byte-identical to a fault-free build).
    pub resilience: ResilienceConfig,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            rms: RmsConfig::default(),
            mode: SchedMode::Sync,
            costs: CostModel::default(),
            exec: ExecModel::default(),
            seed: 0xD41,
            resilience: ResilienceConfig::default(),
        }
    }
}

/// Per-action timing statistics (Table 2).
#[derive(Debug, Clone, Default)]
pub struct ActionStats {
    /// Decision-only costs of no-action calls.
    pub no_action: Summary,
    /// End-to-end expansion times (wait + protocol).
    pub expand: Summary,
    /// End-to-end shrink times.
    pub shrink: Summary,
    /// Expansions abandoned at the resizer-job timeout.
    pub expand_aborts: u64,
}

/// Everything measured from one workload run.
pub struct RunResult {
    /// Run label (scenario + seed for campaigns).
    pub label: String,
    /// The final manager state (job records, event log, telemetry).
    pub rms: Rms,
    /// Completion time of the last job.
    pub makespan: Time,
    /// Arrival time of the first job.
    pub first_submit: Time,
    /// Per-action timing statistics.
    pub actions: ActionStats,
    /// User jobs processed.
    pub user_jobs: usize,
    /// Discrete events processed (arrivals, checks, completions, resize
    /// commits, retries, machine fault events — including stale ones).
    /// Deterministic for a given workload + config; the denominator of
    /// events/s.
    pub events: u64,
    /// Fault-injection measures (all zero / availability 1.0 when the
    /// resilience config is inactive).
    pub resilience: ResilienceStats,
    /// High-water mark of live simulation-slab slots (started,
    /// not-yet-completed jobs).  Bounded by peak concurrency — on a
    /// streamed run this stays flat no matter how many jobs replay.
    pub peak_slab: usize,
    /// Host-side wall-clock profile of the engine's hot phases.  Purely
    /// observational (no RNG, no heap, no effect on the event stream);
    /// values are timing noise and must never enter deterministic
    /// outputs — see [`crate::obs::profile`].
    pub profile: PhaseProfile,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EvKind {
    Arrival(usize),
    Check,
    Complete,
    ResizeDone { to: usize, expand: bool, began: Time },
    /// One phase boundary of an active resize transaction (multi-phase
    /// path only; `step` is a `resilience::resize::PHASE_*` code).  The
    /// transaction state itself lives on the [`SimJob`].
    ResizePhase { step: u8 },
    ExpandRetry { to: usize, began: Time, deadline: Time },
    /// Machine events (job field is 0): a node fails; `auto` failures
    /// belong to the MTBF sampling chain and schedule their own repair +
    /// next failure.
    NodeFail { node: NodeId, auto: bool },
    NodeRepair { node: NodeId },
    /// Drain window `i` of the fault spec starts / ends.
    DrainStart(usize),
    DrainEnd(usize),
    /// A rescued job finished its post-failure redistribution and resumes.
    Resume,
    /// A correlated outage on failure domain `dom` of the event's shard
    /// starts (`dom` indexes [`Shard::domain_nodes`]; 0 is the implicit
    /// whole shard).  `auto` outages belong to the domain-MTBF chain and
    /// schedule their own end + next outage.
    OutageStart { dom: usize, auto: bool },
    /// The matching outage ends: the domain's nodes repair (subject to
    /// nesting with node faults and drains).
    OutageEnd { dom: usize },
    /// A network partition isolates the event's shard: it keeps running
    /// local work but routing and stealing skip it until the window ends.
    PartitionStart,
    PartitionEnd,
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: Time,
    seq: u64,
    /// Owning shard (0 in the flat engine).  Arrival events ignore it —
    /// the meta-scheduler routes them when they are *popped*, so
    /// load-sensitive policies see current state.
    shard: usize,
    job: JobId,
    epoch: u64,
    kind: EvKind,
}

// Order by time (then sequence) for the min-heap.
impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.seq.cmp(&o.seq))
    }
}

/// The copyable subset of a [`JobSpec`] the simulation needs per event —
/// extracting it once at start time keeps the slab string-free and makes
/// iteration-time math allocation-free.
#[derive(Debug, Clone, Copy)]
struct SimSpec {
    iterations: u32,
    /// Pre-resolved `spec.work_per_iter()` (same float ops, same value),
    /// scaled by the owning shard's `1/speed` (exactly `1.0` on the flat
    /// path and default shards).
    work_per_iter: f64,
    alpha: f64,
    sched_period: f64,
    min_procs: usize,
    max_procs: usize,
    pref_procs: Option<usize>,
    factor: usize,
    malleable: bool,
}

impl SimSpec {
    fn of(spec: &JobSpec) -> Self {
        SimSpec {
            iterations: spec.iterations,
            work_per_iter: spec.work_per_iter(),
            alpha: spec.alpha,
            sched_period: spec.sched_period,
            min_procs: spec.min_procs,
            max_procs: spec.max_procs,
            pref_procs: spec.pref_procs,
            factor: spec.factor,
            malleable: spec.malleable,
        }
    }
}

/// An in-flight multi-phase resize transaction (allocation grant → spawn
/// → redistribute → commit).  Exists only on the fault-injected path —
/// with an inactive [`ResizeFaultSpec`] resizes keep the legacy single
/// `ResizeDone` event and this is never constructed.
#[derive(Debug, Clone, Copy)]
struct ResizeTxn {
    /// Target process count.
    to: usize,
    /// Pre-transaction process count (the rollback target).
    from: usize,
    expand: bool,
    /// When the granting DMR call happened (expand-time measurement base).
    began: Time,
    /// Fault outcomes for this transaction, pre-drawn at launch and
    /// indexed by phase code (grant / spawn / redistribute).
    fails: [bool; 3],
    /// Absolute end of the spawn phase: `launch + action_sched`.
    spawn_at: Time,
    /// Absolute end of the redistribution phase — computed as
    /// `launch + sched + transfer` with the exact expression the legacy
    /// path uses, so a fault-free transaction commits on the very same
    /// float the single `ResizeDone` event would have carried.
    commit_at: Time,
}

struct SimJob {
    spec: SimSpec,
    procs: usize,
    iters_done: f64,
    last_t: Time,
    running: bool,
    epoch: u64,
    inhibitor: Inhibitor,
    pending_async: Option<Action>,
    /// Active resize transaction, if any (multi-phase path only).
    txn: Option<ResizeTxn>,
    /// Consecutive aborted transactions; reset on commit, drives the
    /// bounded exponential backoff and the degradation threshold.
    resize_attempt: u32,
    /// Memoized `iter_time` at `memo_procs` processes.
    memo_procs: usize,
    memo_iter: f64,
    /// Accumulated execution (running) time — the checkpoint/rework model
    /// rolls this back on failures.
    run_time_acc: f64,
    /// Progress at the last checkpoint: execution time (a multiple of the
    /// checkpoint interval) and the iterations held then.  Recorded by
    /// `progress` at the rate the work was actually earned, so rollback
    /// is exact even when resizes changed the iteration rate since.
    ckpt_run_time: f64,
    ckpt_iters: f64,
}

impl SimJob {
    fn remaining(&self) -> f64 {
        (self.spec.iterations as f64 - self.iters_done).max(0.0)
    }

    /// Seconds per iteration at the current size; recomputed only when a
    /// resize changed `procs` since the last call.
    fn iter_time(&mut self, exec: &ExecModel) -> f64 {
        if self.memo_procs != self.procs {
            self.memo_iter =
                exec.iter_time_raw(self.spec.work_per_iter, self.spec.alpha, self.procs);
            self.memo_procs = self.procs;
        }
        self.memo_iter
    }
}

const NO_SLOT: u32 = u32::MAX;

/// Heap-tiebreaker floor for non-arrival events.  Arrivals carry their
/// pull ordinal (0-based) as `seq`; every other event gets
/// `ARRIVAL_FLOOR + counter`.  At equal times arrivals therefore always
/// pop first, in submit order, regardless of *when* the look-ahead
/// window pushed them — which makes the pop order (and the whole event
/// stream) independent of the window size: streamed ≡ materialized.
const ARRIVAL_FLOOR: u64 = 1 << 63;

/// Golden-ratio sequence salt for per-shard RNG streams: distinct per
/// shard, and zero for shard 0 — the flat path's streams are untouched.
fn shard_salt(id: usize) -> u64 {
    (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One shard of the (possibly 1-shard) federation: its own manager,
/// RNG streams, fault timeline and simulation slab.
struct Shard {
    rms: Rms,
    /// Cost-jitter stream (salted by shard id).
    rng: Rng,
    /// Dedicated RNG for the MTBF/MTTR fault chains — a separate stream so
    /// fault timelines are identical across scheduling modes and the cost
    /// stream of fault-free runs is untouched.
    fault_rng: Rng,
    /// This shard's fault sources (MTBF scaled by the shard spec).
    faults: FaultSpec,
    /// Whether any fault source is configured.
    faults_active: bool,
    /// This shard's correlated-outage sources (failure domains, scripted
    /// outages/partitions, domain-MTBF sampling).
    outages: OutageSpec,
    /// Dedicated RNG for the domain-outage chains — its own salted stream
    /// ([`OutageSpec::rng`]), so enabling outages perturbs neither the
    /// cost jitter nor the per-node fault timeline.
    outage_rng: Rng,
    /// Whether the outage spec injects anything; `false` keeps every
    /// outage structure empty and the event stream byte-identical to an
    /// outage-free build.
    outages_active: bool,
    /// Whether checkpoint bookkeeping is needed at all: node faults *or*
    /// outages can interrupt work on this shard.  `false` keeps the
    /// fault-free hot path free of it.
    ckpt_active: bool,
    /// Resolved node lists per failure domain.  Index 0 is always the
    /// implicit whole-shard domain; explicit domains follow in spec
    /// order.  Empty when outages are inactive.
    domain_nodes: Vec<Vec<NodeId>>,
    /// Outages currently dark on this shard.  Routing, stealing and
    /// evacuation skip the shard while this is nonzero.
    outage_depth: u32,
    /// Partition windows currently isolating this shard (reachability
    /// only — local execution continues).
    partition_depth: u32,
    /// Jobs evacuated into / out of this shard during outages.
    evac_in: u64,
    evac_out: u64,
    /// Resize-transaction fault injection + retry policy.
    resize_faults: ResizeFaultSpec,
    /// Dedicated RNG for transaction fault draws — its own stream, so an
    /// active resize-fault spec perturbs neither the cost jitter nor the
    /// machine-fault timeline.
    resize_rng: Rng,
    /// Whether the spec injects anything; `false` keeps every resize on
    /// the legacy single-event path (byte-identical event stream).
    resize_active: bool,
    /// Relative node speed (reporting only; the reciprocal below does the
    /// work).
    speed: f64,
    /// `1/speed`, folded into every `SimSpec::work_per_iter` and runtime
    /// estimate on this shard.  Exactly `1.0` on the flat path.
    inv_speed: f64,
    /// Dense per-job simulation slab, one slot per *live* started job —
    /// completed jobs' slots are recycled via `free_slots`, so the slab
    /// is bounded by peak concurrency, not total jobs processed.
    sims: Vec<SimJob>,
    /// JobId → slab slot (`NO_SLOT` = not simulated: resizers, unstarted,
    /// completed).
    slot_of: Vec<u32>,
    /// Recycled slab slots of completed jobs, reused LIFO.
    free_slots: Vec<u32>,
    /// High-water mark of live slab slots (`sims.len() - free_slots.len()`).
    slab_peak: usize,
    /// Resolved node lists of the fault spec's drain windows.
    drain_nodes: Vec<Vec<NodeId>>,
    /// Per-node count of drain windows currently covering the node.
    drain_depth: Vec<u32>,
    /// Per-node count of failures awaiting repair.  Failures and repairs
    /// pair 1:1 (each auto failure schedules its own chain repair; each
    /// scripted failure carries at most one scripted repair), so
    /// overlapping outages nest correctly: the node returns only when
    /// every outage that hit it has been repaired — and never, for a
    /// scripted failure with no repair.  Drain ends must not resurrect a
    /// node while this is nonzero.
    fail_depth: Vec<u32>,
    /// Down-node integral of this shard as of the engine's `down_last_t`.
    down_acc: f64,
    stats: ResilienceStats,
    /// Jobs stolen into / out of this shard, arrivals routed here.
    steals_in: u64,
    steals_out: u64,
    routed: u64,
}

impl Shard {
    fn new(
        id: usize,
        nodes: usize,
        speed: f64,
        faults: FaultSpec,
        strategy: Option<PolicyStrategy>,
        outages: OutageSpec,
        cfg: &DesConfig,
    ) -> Self {
        let mut rms_cfg = cfg.rms.clone();
        rms_cfg.nodes = nodes;
        if let Some(st) = strategy {
            // Per-shard policy override (`nodes:speed:mtbf:strategy` in
            // the topology string); `None` inherits the global strategy.
            rms_cfg.strategy = st;
        }
        let salt = shard_salt(id);
        let faults_active = faults.is_active();
        let drain_nodes = faults.drains.iter().map(|w| w.nodes.node_ids(nodes)).collect();
        let resize_faults = cfg.resilience.resize_faults.clone();
        let resize_rng = resize_faults.rng(cfg.seed ^ salt);
        let resize_active = resize_faults.is_active();
        let outages_active = outages.is_active();
        let outage_rng = outages.rng(cfg.seed ^ salt);
        let mut domain_nodes: Vec<Vec<NodeId>> = Vec::new();
        if outages_active {
            domain_nodes.push((0..nodes).collect());
            for d in &outages.domains {
                domain_nodes.push(d.nodes.node_ids(nodes));
            }
        }
        Shard {
            rms: Rms::new(rms_cfg),
            rng: Rng::new(cfg.seed ^ salt),
            fault_rng: faults.rng(cfg.seed ^ salt),
            faults,
            faults_active,
            outages,
            outage_rng,
            outages_active,
            ckpt_active: faults_active || outages_active,
            domain_nodes,
            outage_depth: 0,
            partition_depth: 0,
            evac_in: 0,
            evac_out: 0,
            resize_faults,
            resize_rng,
            resize_active,
            speed,
            inv_speed: 1.0 / speed,
            sims: Vec::new(),
            slot_of: Vec::new(),
            free_slots: Vec::new(),
            slab_peak: 0,
            drain_nodes,
            drain_depth: vec![0; nodes],
            fail_depth: vec![0; nodes],
            down_acc: 0.0,
            stats: ResilienceStats::default(),
            steals_in: 0,
            steals_out: 0,
            routed: 0,
        }
    }

    fn slot(&self, id: JobId) -> Option<usize> {
        match self.slot_of.get(id as usize) {
            Some(&s) if s != NO_SLOT => Some(s as usize),
            _ => None,
        }
    }

    fn insert_sim(&mut self, id: JobId, sim: SimJob) {
        let idx = id as usize;
        if self.slot_of.len() <= idx {
            self.slot_of.resize(idx + 1, NO_SLOT);
        }
        debug_assert_eq!(self.slot_of[idx], NO_SLOT, "job {id} simulated twice");
        let slot = match self.free_slots.pop() {
            Some(free) => {
                self.sims[free as usize] = sim;
                free
            }
            None => {
                self.sims.push(sim);
                (self.sims.len() - 1) as u32
            }
        };
        self.slot_of[idx] = slot;
        self.slab_peak = self.slab_peak.max(self.sims.len() - self.free_slots.len());
    }

    /// Release a completed job's slab slot for reuse.  Only terminal
    /// completions free slots — requeued/rescued jobs keep theirs (the
    /// checkpointed progress lives there until the job finishes).
    fn free_sim(&mut self, id: JobId) {
        let idx = id as usize;
        let slot = self.slot_of[idx];
        debug_assert_ne!(slot, NO_SLOT, "freeing an unsimulated job");
        self.slot_of[idx] = NO_SLOT;
        self.free_slots.push(slot);
    }

    /// Resolve a scripted outage's domain name to its
    /// [`Shard::domain_nodes`] index (`""`/`"shard"`/`"all"` name the
    /// implicit whole-shard domain).  Unknown names resolve to `None` —
    /// the campaign parser validates them; the engine just skips.
    fn resolve_domain(&self, name: &str) -> Option<usize> {
        match name {
            "" | "shard" | "all" => Some(0),
            n => self.outages.domains.iter().position(|d| d.name == n).map(|i| i + 1),
        }
    }

    /// Whether the meta-scheduler may send work here: not dark, not
    /// partitioned.  Always `true` when outages are inactive (both depths
    /// stay 0), so the outage-free paths are untouched.
    fn reachable(&self) -> bool {
        self.outage_depth == 0 && self.partition_depth == 0
    }
}

/// The engine.
pub struct Engine {
    cfg: DesConfig,
    /// The shard vector; the flat engine is exactly `shards.len() == 1`.
    shards: Vec<Shard>,
    routing: RoutingPolicy,
    steal: StealPolicy,
    /// Round-robin routing cursor.
    rr_next: usize,
    heap: BinaryHeap<Reverse<Ev>>,
    down_last_t: Time,
    now: Time,
    seq: u64,
    events: u64,
    actions: ActionStats,
    done: usize,
    user_jobs: usize,
    first_submit: Time,
    /// Wall-clock phase counters (observational only — never read by the
    /// simulation).
    profile: PhaseProfile,
}

impl Engine {
    /// Build a flat (1-shard) engine — fresh RMS + seeded RNG streams —
    /// for one run.
    pub fn new(cfg: DesConfig) -> Self {
        let shard = Shard::new(
            0,
            cfg.rms.nodes,
            1.0,
            cfg.resilience.faults.clone(),
            None,
            OutageSpec::default(),
            &cfg,
        );
        Engine::with_shards(cfg, vec![shard], RoutingPolicy::RoundRobin, StealPolicy::Off)
    }

    /// Build a federated engine: one shard per [`FederationConfig`]
    /// entry, MTBF scaled per shard (or overridden by `shard_faults`).
    pub(crate) fn new_federated(cfg: DesConfig, fed: &FederationConfig) -> Self {
        let shards = fed
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let faults = match fed.shard_faults.as_ref().and_then(|v| v.get(i)) {
                    Some(f) => f.clone(),
                    None => {
                        let mut f = cfg.resilience.faults.clone();
                        f.mtbf *= s.mtbf_scale;
                        f
                    }
                };
                let outages = fed
                    .outages
                    .as_ref()
                    .and_then(|v| v.get(i))
                    .cloned()
                    .unwrap_or_default();
                Shard::new(i, s.nodes, s.speed, faults, s.strategy, outages, &cfg)
            })
            .collect();
        Engine::with_shards(cfg, shards, fed.routing, fed.steal)
    }

    fn with_shards(
        cfg: DesConfig,
        shards: Vec<Shard>,
        routing: RoutingPolicy,
        steal: StealPolicy,
    ) -> Self {
        Engine {
            cfg,
            shards,
            routing,
            steal,
            rr_next: 0,
            heap: BinaryHeap::new(),
            down_last_t: 0.0,
            now: 0.0,
            seq: 0,
            events: 0,
            actions: ActionStats::default(),
            done: 0,
            user_jobs: 0,
            first_submit: f64::INFINITY,
            profile: PhaseProfile::new(),
        }
    }

    /// Direct access to the machine (failure-injection tests mark nodes
    /// down before arrivals).
    pub fn cluster_mut(&mut self) -> &mut crate::cluster::Cluster {
        &mut self.shards[0].rms.cluster
    }

    /// Direct access to one shard's machine (federated tests).
    pub(crate) fn shard_cluster_mut(&mut self, shard: usize) -> &mut crate::cluster::Cluster {
        &mut self.shards[shard].rms.cluster
    }

    fn push(&mut self, t: Time, shard: usize, job: JobId, epoch: u64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq: ARRIVAL_FLOOR + self.seq, shard, job, epoch, kind }));
    }

    /// Push one arrival event; `seq` is the pull ordinal (below
    /// [`ARRIVAL_FLOOR`]), keeping pop order window-independent.
    fn push_arrival(&mut self, t: Time, ordinal: u64) {
        debug_assert!(ordinal < ARRIVAL_FLOOR, "arrival ordinal overflow");
        self.heap.push(Reverse(Ev {
            t,
            seq: ordinal,
            shard: 0,
            job: 0,
            epoch: 0,
            kind: EvKind::Arrival(ordinal as usize),
        }));
    }

    /// Run a workload to completion; returns the measurements.
    ///
    /// The batch compatibility path: equivalent to [`Engine::run_stream`]
    /// over a [`Materialized`] stream with an infinite look-ahead window,
    /// and bit-identical to it (same event stream, same log digest).
    pub fn run(self, workload: &WorkloadSpec, label: &str) -> RunResult {
        let mut stream = Materialized::from(workload);
        self.run_stream(&mut stream, usize::MAX, label)
            .expect("materialized stream cannot fail")
    }

    /// Run a job stream to completion, holding at most `window` unarrived
    /// jobs resident (peak resident jobs ≈ active jobs + `window`).
    ///
    /// Errors propagate from the stream only (e.g. a malformed or
    /// out-of-order SWF trace); the engine itself is infallible.  Any
    /// `window ≥ 1` produces the same result bit-for-bit.
    pub fn run_stream(
        mut self,
        stream: &mut dyn JobStream,
        window: usize,
        label: &str,
    ) -> anyhow::Result<RunResult> {
        debug_assert_eq!(self.shards.len(), 1, "flat run on a federated engine");
        self.run_loop(stream, window)?;
        let sh = self.shards.pop().expect("flat engine owns one shard");
        Ok(RunResult {
            label: label.to_string(),
            makespan: self.now,
            first_submit: self.first_submit,
            actions: self.actions,
            user_jobs: self.user_jobs,
            events: self.events,
            resilience: sh.stats,
            peak_slab: sh.slab_peak,
            rms: sh.rms,
            profile: self.profile,
        })
    }

    /// Run a workload to completion across the federation; returns the
    /// global measures plus one [`ShardRun`] per shard.
    pub(crate) fn run_federated(self, workload: &WorkloadSpec, label: &str) -> FedRunResult {
        let mut stream = Materialized::from(workload);
        self.run_stream_federated(&mut stream, usize::MAX, label)
            .expect("materialized stream cannot fail")
    }

    /// Streamed counterpart of [`Engine::run_federated`]: pull arrivals
    /// lazily with a bounded look-ahead window.
    pub(crate) fn run_stream_federated(
        mut self,
        stream: &mut dyn JobStream,
        window: usize,
        label: &str,
    ) -> anyhow::Result<FedRunResult> {
        self.run_loop(stream, window)?;
        let makespan = self.now;
        let mut merged = ResilienceStats::default();
        let mut capacity = 0.0;
        let mut lost = 0.0;
        for sh in &self.shards {
            merged.node_failures += sh.stats.node_failures;
            merged.interrupted += sh.stats.interrupted;
            merged.rescued += sh.stats.rescued;
            merged.requeued += sh.stats.requeued;
            merged.evacuated += sh.stats.evacuated;
            merged.rework_time += sh.stats.rework_time;
            merged.resize_attempts += sh.stats.resize_attempts;
            merged.resize_aborts += sh.stats.resize_aborts;
            merged.retry_time += sh.stats.retry_time;
            merged.degraded_jobs += sh.stats.degraded_jobs;
            lost += sh.stats.lost_node_seconds;
            capacity += sh.rms.cluster.total() as f64 * makespan;
        }
        merged.lost_node_seconds = lost;
        merged.availability =
            if capacity > 0.0 { (1.0 - lost / capacity).max(0.0) } else { 1.0 };
        let peak_slab: usize = self.shards.iter().map(|sh| sh.slab_peak).sum();
        let shards = self
            .shards
            .into_iter()
            .enumerate()
            .map(|(i, sh)| ShardRun {
                shard: i,
                nodes: sh.rms.cluster.total(),
                speed: sh.speed,
                stats: sh.stats,
                steals_in: sh.steals_in,
                steals_out: sh.steals_out,
                routed: sh.routed,
                evac_in: sh.evac_in,
                evac_out: sh.evac_out,
                rms: sh.rms,
            })
            .collect();
        Ok(FedRunResult {
            label: label.to_string(),
            makespan,
            first_submit: self.first_submit,
            actions: self.actions,
            user_jobs: self.user_jobs,
            events: self.events,
            resilience: merged,
            peak_slab,
            shards,
            profile: self.profile,
        })
    }

    /// Pull one job from the stream into the look-ahead window: push its
    /// arrival event and park the spec in `pending` (popped again, in
    /// ordinal order, when the arrival event fires).  Returns `Ok(false)`
    /// once the stream is exhausted.
    fn pull_arrival(
        &mut self,
        stream: &mut dyn JobStream,
        pending: &mut VecDeque<(u64, JobSpec)>,
        pulled: &mut u64,
        last_submit: &mut f64,
    ) -> anyhow::Result<bool> {
        let Some(spec) = stream.next_job()? else { return Ok(false) };
        assert!(
            spec.submit_time >= *last_submit,
            "job stream must be submit-ordered: {} after {}",
            spec.submit_time,
            *last_submit
        );
        *last_submit = spec.submit_time;
        self.user_jobs += 1;
        self.push_arrival(spec.submit_time, *pulled);
        pending.push_back((*pulled, spec));
        *pulled += 1;
        Ok(true)
    }

    /// The shared event loop (flat and federated paths): arrivals are
    /// pulled lazily from `stream`, at most `window` unarrived jobs
    /// resident at a time.  The window is refilled whenever an arrival
    /// pops — the next arrival's submit time is ≥ `now`, so the heap
    /// always holds it before any later-time event can pop, which is why
    /// every `window ≥ 1` yields an identical event stream.
    fn run_loop(&mut self, stream: &mut dyn JobStream, window: usize) -> anyhow::Result<()> {
        let window = window.max(1);
        let mut pending: VecDeque<(u64, JobSpec)> = VecDeque::new();
        let mut pulled: u64 = 0;
        let mut last_submit = f64::NEG_INFINITY;
        let mut stream_done = false;
        while pending.len() < window && !stream_done {
            stream_done =
                !self.pull_arrival(stream, &mut pending, &mut pulled, &mut last_submit)?;
        }
        self.seed_fault_events();

        // Deadlock guard: with MTBF chains the heap never empties, so a
        // workload that can never drain (e.g. a permanently-failed node
        // leaving a job unplaceable) would spin forever instead of
        // hitting the drain assert below.  No plausible configuration
        // processes this many events between two job completions.
        const STUCK_EVENTS: u64 = 5_000_000;
        let mut last_done_at: u64 = 0;
        let mut last_done: usize = 0;
        let steal_on = self.steal.enabled() && self.shards.len() > 1;

        while let Some(Reverse(ev)) = self.heap.pop() {
            debug_assert!(ev.t >= self.now - 1e-9, "time went backwards");
            self.now = ev.t.max(self.now);
            self.events += 1;
            if self.done != last_done {
                last_done = self.done;
                last_done_at = self.events;
            } else if self.events - last_done_at > STUCK_EVENTS {
                panic!(
                    "no job completed in {STUCK_EVENTS} events ({}/{} done, t={}): \
                     the fault spec has likely made the workload unplaceable",
                    self.done, self.user_jobs, self.now
                );
            }
            // Integrate machine unavailability up to this instant (O(1)
            // per shard: the down count is a maintained counter).
            for sh in &mut self.shards {
                let down = sh.rms.cluster.down();
                if down > 0 {
                    sh.down_acc += down as f64 * (self.now - self.down_last_t);
                }
            }
            self.down_last_t = self.now;
            let t_dispatch = std::time::Instant::now();
            match ev.kind {
                EvKind::Arrival(ord) => {
                    let (o, spec) =
                        pending.pop_front().expect("arrival event without a pulled spec");
                    debug_assert_eq!(o as usize, ord, "arrival order mismatch");
                    // Refill before handling, so the heap always holds
                    // the next unarrived job (the window-1 invariant).
                    if !stream_done {
                        stream_done = !self
                            .pull_arrival(stream, &mut pending, &mut pulled, &mut last_submit)?;
                    }
                    let s = self.route(&spec);
                    self.on_arrival(s, spec);
                }
                EvKind::Check => self.on_check(ev),
                EvKind::Complete => self.on_complete(ev),
                EvKind::ResizeDone { to, expand, began } => {
                    self.on_resize_done(ev, to, expand, began)
                }
                EvKind::ResizePhase { step } => self.on_resize_phase(ev, step),
                EvKind::ExpandRetry { to, began, deadline } => {
                    self.on_expand_retry(ev, to, began, deadline)
                }
                EvKind::NodeFail { node, auto } => self.on_node_fail(ev.shard, node, auto),
                EvKind::NodeRepair { node } => self.on_node_repair(ev.shard, node),
                EvKind::DrainStart(w) => self.on_drain_start(ev.shard, w),
                EvKind::DrainEnd(w) => self.on_drain_end(ev.shard, w),
                EvKind::Resume => self.on_resume(ev),
                EvKind::OutageStart { dom, auto } => self.on_outage_start(ev.shard, dom, auto),
                EvKind::OutageEnd { dom } => self.on_outage_end(ev.shard, dom),
                EvKind::PartitionStart => self.on_partition_start(ev.shard),
                EvKind::PartitionEnd => self.on_partition_end(ev.shard),
            }
            if steal_on {
                self.try_steal();
            }
            self.profile
                .record(Phase::Dispatch, t_dispatch.elapsed().as_nanos() as u64);
            if self.done == self.user_jobs && stream_done && pending.is_empty() {
                break;
            }
        }
        assert_eq!(self.done, self.user_jobs, "workload did not drain");

        for sh in &mut self.shards {
            sh.stats.lost_node_seconds = sh.down_acc;
            let capacity = sh.rms.cluster.total() as f64 * self.now;
            sh.stats.availability =
                if capacity > 0.0 { (1.0 - sh.down_acc / capacity).max(0.0) } else { 1.0 };
            sh.rms.seal_metrics(self.now);
        }
        Ok(())
    }

    /// Seed the machine-event streams: scripted fault-trace events, drain
    /// windows, and (when MTBF sampling is on) each node's first failure
    /// — per shard, in shard-id order.  Pushed *after* the arrivals so
    /// fault-free heaps are identical to pre-resilience builds.
    fn seed_fault_events(&mut self) {
        for s in 0..self.shards.len() {
            let faults = self.shards[s].faults.clone();
            if faults.is_active() {
                let total = self.shards[s].rms.cluster.total();
                for ev in &faults.scripted {
                    if ev.node >= total {
                        continue;
                    }
                    let kind = match ev.kind {
                        FaultKind::Fail => EvKind::NodeFail { node: ev.node, auto: false },
                        FaultKind::Repair => EvKind::NodeRepair { node: ev.node },
                    };
                    self.push(ev.at, s, 0, 0, kind);
                }
                for (i, w) in faults.drains.iter().enumerate() {
                    self.push(w.start, s, 0, 0, EvKind::DrainStart(i));
                    self.push(w.end, s, 0, 0, EvKind::DrainEnd(i));
                }
                let init = faults.initial_failures(total, &mut self.shards[s].fault_rng);
                for (node, at) in init {
                    self.push(at, s, 0, 0, EvKind::NodeFail { node, auto: true });
                }
            }
            let outages = self.shards[s].outages.clone();
            if outages.is_active() {
                // Scripted correlated outages + partition windows, then
                // (when domain-MTBF sampling is on) each sampled domain's
                // first outage — draws in domain order, like the per-node
                // fault seeding above.
                for ev in &outages.scripted {
                    let Some(dom) = self.shards[s].resolve_domain(&ev.domain) else {
                        continue;
                    };
                    self.push(ev.at, s, 0, 0, EvKind::OutageStart { dom, auto: false });
                    self.push(ev.at + ev.duration, s, 0, 0, EvKind::OutageEnd { dom });
                }
                for w in &outages.partitions {
                    self.push(w.start, s, 0, 0, EvKind::PartitionStart);
                    self.push(w.end, s, 0, 0, EvKind::PartitionEnd);
                }
                if outages.mtbf > 0.0 {
                    let sampled =
                        if outages.domains.is_empty() { 1 } else { outages.domains.len() };
                    let init = outages.initial_outages(sampled, &mut self.shards[s].outage_rng);
                    for (d, at) in init {
                        // Sampled domains are the explicit ones (indices
                        // 1..) or, with none declared, the whole shard (0).
                        let dom = if outages.domains.is_empty() { 0 } else { d + 1 };
                        self.push(at, s, 0, 0, EvKind::OutageStart { dom, auto: true });
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Meta-scheduler: routing + work stealing

    /// Pick the shard for an arriving job (trivially shard 0 on the flat
    /// path).  Shards whose whole pool is smaller than the job's
    /// `min_procs` — or that are currently dark or partitioned — are
    /// skipped; if none qualifies the largest shard takes the job (the
    /// per-shard `fit_spec` clamp keeps it placeable; an unreachable
    /// fallback shard just queues it until recovery).
    fn route(&mut self, spec: &JobSpec) -> usize {
        let k = self.shards.len();
        if k == 1 {
            return 0;
        }
        let placeable = |sh: &Shard| sh.reachable() && spec.min_procs <= sh.rms.cluster.total();
        let pick = match self.routing {
            RoutingPolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..k {
                    let s = self.rr_next % k;
                    self.rr_next = (self.rr_next + 1) % k;
                    if placeable(&self.shards[s]) {
                        pick = Some(s);
                        break;
                    }
                }
                pick
            }
            RoutingPolicy::LeastLoaded => {
                let mut best: Option<(f64, usize)> = None;
                for (i, sh) in self.shards.iter().enumerate() {
                    if !placeable(sh) {
                        continue;
                    }
                    let load = (sh.rms.pending_user_jobs() + sh.rms.running_jobs()) as f64
                        / sh.rms.cluster.total() as f64;
                    let better = match best {
                        Some((b, _)) => load.total_cmp(&b).is_lt(),
                        None => true,
                    };
                    if better {
                        best = Some((load, i));
                    }
                }
                best.map(|(_, i)| i)
            }
            RoutingPolicy::Locality => {
                let home = spec.user as usize % k;
                (0..k).map(|d| (home + d) % k).find(|&s| placeable(&self.shards[s]))
            }
        };
        pick.unwrap_or_else(|| {
            let mut best = 0;
            for i in 1..k {
                if self.shards[i].rms.cluster.total() > self.shards[best].rms.cluster.total() {
                    best = i;
                }
            }
            best
        })
    }

    /// One steal attempt (invoked after every processed event when
    /// stealing is on): the lowest-id *drained* shard (no pending user
    /// jobs, free nodes) takes pending work from the most-backlogged
    /// shard — the head job under [`StealPolicy::Head`], up to half the
    /// victim's backlog under [`StealPolicy::Half`].  Dark or partitioned
    /// shards participate on neither side.  Each stolen job re-submits
    /// through the thief's normal clamp/priority path with its original
    /// submission time, so aging carries over; any checkpoint state stays
    /// behind (a restart on the thief is the conservative model of a
    /// cross-cluster migration).
    fn try_steal(&mut self) {
        let thief = self.shards.iter().position(|sh| {
            sh.reachable() && sh.rms.pending_user_jobs() == 0 && sh.rms.cluster.available() > 0
        });
        let Some(t) = thief else { return };
        let mut victim: Option<(usize, usize)> = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if i == t || !sh.reachable() {
                continue;
            }
            let p = sh.rms.pending_user_jobs();
            if p == 0 {
                continue;
            }
            if victim.map(|(_, best)| p > best).unwrap_or(true) {
                victim = Some((i, p));
            }
        }
        let Some((v, backlog)) = victim else { return };
        let budget = match self.steal {
            StealPolicy::Off => return,
            StealPolicy::Head => 1,
            // Half the backlog, rounded up — the classic work-stealing
            // split, amortizing the per-steal protocol cost.
            StealPolicy::Half => (backlog + 1) / 2,
        };
        let now = self.now;
        let mut stole = 0usize;
        for _ in 0..budget {
            let free = self.shards[t].rms.cluster.available();
            if free == 0 {
                break;
            }
            let Some(cand) = self.shards[v].rms.steal_candidate(free, now) else { break };
            let Some((mut spec, submitted)) = self.shards[v].rms.withdraw(cand, now) else {
                break;
            };
            self.shards[v].steals_out += 1;
            fit_spec(&mut spec, self.shards[t].rms.cluster.total());
            let est = self.cfg.exec.exec_time(&spec, spec.procs) * self.shards[t].inv_speed;
            let id = self.shards[t].rms.submit(spec, submitted);
            self.shards[t].rms.set_expected_end(id, now + est);
            self.shards[t].steals_in += 1;
            stole += 1;
        }
        // Schedule only when something moved: a fruitless attempt must
        // leave the thief's pass counters untouched (bit-compatibility
        // with the single-steal engine).
        if stole > 0 {
            self.try_schedule(t);
        }
    }

    // ------------------------------------------------------------------

    fn on_arrival(&mut self, s: usize, mut spec: JobSpec) {
        self.first_submit = self.first_submit.min(self.now);
        if self.shards.len() > 1 {
            // Per-shard clamp: the job must fit the shard it landed on
            // (the flat path never refits — bit-compatibility).
            fit_spec(&mut spec, self.shards[s].rms.cluster.total());
        }
        // Estimate for backfill: duration at the requested size, on this
        // shard's hardware.
        let est = self.cfg.exec.exec_time(&spec, spec.procs) * self.shards[s].inv_speed;
        let id = self.shards[s].rms.submit(spec, self.now);
        self.shards[s].rms.set_expected_end(id, self.now + est);
        self.shards[s].routed += 1;
        self.try_schedule(s);
    }

    fn try_schedule(&mut self, s: usize) {
        let t0 = std::time::Instant::now();
        self.shards[s].rms.schedule(self.now);
        self.profile.record(Phase::Schedule, t0.elapsed().as_nanos() as u64);
        self.drain_started(s);
    }

    /// Materialize sims for every start shard `s`'s RMS has made that
    /// this driver has not picked up yet.  Scheduling passes can run
    /// *inside* `dmr_check` (the resizer-job protocol), so machine-event
    /// handlers call this before touching victims — every active job then
    /// has a slab slot.
    fn drain_started(&mut self, s: usize) {
        let started = self.shards[s].rms.take_recent_starts();
        for st in started {
            // `is_active()` filters starts already invalidated by a node
            // failure that requeued the job before this buffer drained
            // (it will start again — and get its sim — via a later pass).
            let (spec, malleable, procs) = match self.shards[s].rms.job(st.job) {
                Some(j) if !j.is_resizer && j.is_active() => {
                    let mut sp = SimSpec::of(&j.spec);
                    sp.work_per_iter *= self.shards[s].inv_speed;
                    (sp, j.spec.malleable, j.procs())
                }
                _ => continue,
            };
            let iter_t = self.cfg.exec.iter_time_raw(spec.work_per_iter, spec.alpha, procs);
            let period = spec.sched_period;
            if let Some(slot) = self.shards[s].slot(st.job) {
                // Restart after a failure requeue: the slab slot survives
                // and keeps the checkpointed progress (`iters_done` /
                // `run_time_acc`); everything else resets.
                {
                    let j = &mut self.shards[s].sims[slot];
                    debug_assert!(!j.running, "restarted job was still running");
                    j.procs = procs;
                    j.inhibitor = Inhibitor::new(period);
                    j.pending_async = None;
                    j.txn = None;
                }
                self.resume_sim(s, slot, st.job);
                continue;
            }
            let sim = SimJob {
                spec,
                procs,
                iters_done: 0.0,
                last_t: self.now,
                running: true,
                epoch: 0,
                inhibitor: Inhibitor::new(period),
                pending_async: None,
                txn: None,
                resize_attempt: 0,
                memo_procs: procs,
                memo_iter: iter_t,
                run_time_acc: 0.0,
                ckpt_run_time: 0.0,
                ckpt_iters: 0.0,
            };
            let complete_at = self.now + sim.remaining() * iter_t;
            self.shards[s].rms.set_expected_end(st.job, complete_at);
            self.shards[s].insert_sim(st.job, sim);
            self.push(complete_at, s, st.job, 0, EvKind::Complete);
            if malleable {
                let check_at = self.now + iter_t.max(period).max(1e-3);
                self.push(check_at, s, st.job, 0, EvKind::Check);
            }
        }
    }

    /// Put a paused sim back to work at its current size: bump the epoch
    /// (invalidating every outstanding event), reschedule its completion
    /// and — for malleable jobs — its next DMR check.
    fn resume_sim(&mut self, s: usize, slot: usize, id: JobId) {
        let exec = &self.cfg.exec;
        let now = self.now;
        let sh = &mut self.shards[s];
        let j = &mut sh.sims[slot];
        j.running = true;
        j.last_t = now;
        j.epoch += 1;
        let epoch = j.epoch;
        let iter_t = j.iter_time(exec);
        let complete_at = now + j.remaining() * iter_t;
        let malleable = j.spec.malleable;
        sh.rms.set_expected_end(id, complete_at);
        self.push(complete_at, s, id, epoch, EvKind::Complete);
        if malleable {
            let next = self.next_check_time(s, slot);
            self.push(next, s, id, epoch, EvKind::Check);
        }
    }

    fn progress(&mut self, s: usize, slot: usize) {
        // Checkpoint bookkeeping only matters when something can fail —
        // a node fault or a correlated outage.
        let ckpt = if self.shards[s].ckpt_active {
            self.cfg.resilience.recovery.checkpoint_interval
        } else {
            0.0
        };
        let exec = &self.cfg.exec;
        let now = self.now;
        let j = &mut self.shards[s].sims[slot];
        if j.running {
            let it = j.iter_time(exec);
            j.iters_done = (j.iters_done + (now - j.last_t) / it).min(j.spec.iterations as f64);
            j.run_time_acc += now - j.last_t;
            if ckpt > 0.0 {
                // Record the newest checkpoint this segment crossed.  The
                // iteration rate is constant within a segment, so the
                // iterations held at the boundary are exact.
                let boundary = (j.run_time_acc / ckpt).floor() * ckpt;
                if boundary > j.ckpt_run_time {
                    let past = j.run_time_acc - boundary;
                    j.ckpt_iters = (j.iters_done - past / it).max(0.0);
                    j.ckpt_run_time = boundary;
                }
            }
        }
        j.last_t = now;
    }

    fn on_complete(&mut self, ev: Ev) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch || !self.shards[s].sims[slot].running {
            return; // stale
        }
        self.progress(s, slot);
        let j = &mut self.shards[s].sims[slot];
        debug_assert!(j.remaining() < 1e-6, "completion with work left");
        j.running = false;
        j.epoch += 1;
        self.shards[s].rms.finish(ev.job, self.now);
        self.done += 1;
        // Terminal: recycle the slab slot.  Stale Complete/Check events
        // for this job id now miss via `slot() == None`, exactly as the
        // epoch check would have caught them.
        self.shards[s].free_sim(ev.job);
        self.try_schedule(s);
    }

    fn on_check(&mut self, ev: Ev) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch || !self.shards[s].sims[slot].running {
            return;
        }
        self.progress(s, slot);
        if self.shards[s].sims[slot].remaining() <= 1e-9 {
            return; // completion event will fire at this same instant
        }
        let spec = self.shards[s].sims[slot].spec;
        let req = DmrRequest {
            min: spec.min_procs,
            max: spec.max_procs,
            pref: spec.pref_procs,
            factor: spec.factor,
        };

        if !self.shards[s].sims[slot].inhibitor.allow(self.now) {
            let epoch = self.shards[s].sims[slot].epoch;
            let next = self.next_check_time(s, slot);
            self.push(next, s, ev.job, epoch, EvKind::Check);
            return;
        }

        let mode = self.cfg.mode;
        let t_dmr = std::time::Instant::now();
        let outcome: Result<DmrOutcome, usize> = match mode {
            SchedMode::Sync => Ok(self.shards[s].rms.dmr_check(ev.job, &req, self.now)),
            SchedMode::Async => {
                let prev = self.shards[s].sims[slot].pending_async.take();
                let next_decision = self.shards[s].rms.dmr_peek(ev.job, &req, self.now);
                self.shards[s].sims[slot].pending_async = Some(next_decision);
                match prev {
                    None | Some(Action::NoAction) => Ok(DmrOutcome::NoAction),
                    Some(a) => match self.shards[s].rms.dmr_apply(ev.job, a, self.now) {
                        Ok(o) => Ok(o),
                        Err(()) => {
                            // Stale expansion: resizer job waits (§5.2.1).
                            let to = match a {
                                Action::Expand { to } => to,
                                _ => unreachable!(),
                            };
                            Err(to)
                        }
                    },
                }
            }
        };
        self.profile.record(Phase::Dmr, t_dmr.elapsed().as_nanos() as u64);

        match outcome {
            Ok(DmrOutcome::NoAction) => {
                let cost = self.cfg.costs.no_action(&mut self.shards[s].rng);
                self.actions.no_action.push(cost);
                // The ~10 ms decision overhead is recorded (Table 2) but
                // not charged against progress: charging it would require
                // rescheduling the completion event for a <0.1 % effect
                // (the inhibitor spaces the calls 15 s apart).
                let epoch = self.shards[s].sims[slot].epoch;
                let next = self.next_check_time(s, slot).max(self.now + cost);
                self.push(next, s, ev.job, epoch, EvKind::Check);
            }
            Ok(DmrOutcome::Expand { to, .. }) => self.begin_resize(s, slot, ev.job, to, true),
            Ok(DmrOutcome::Shrink { to, .. }) => self.begin_resize(s, slot, ev.job, to, false),
            Err(to) => {
                // Pause and retry until the deadline (async wait hazard).
                let j = &mut self.shards[s].sims[slot];
                j.running = false;
                j.epoch += 1;
                let epoch = j.epoch;
                let deadline = self.now + self.cfg.costs.expand_timeout;
                self.push(
                    self.now + 1.0,
                    s,
                    ev.job,
                    epoch,
                    EvKind::ExpandRetry { to, began: self.now, deadline },
                );
            }
        }
    }

    /// Pause the job and launch the granted resize: the legacy
    /// single-event commit when resize faults are inactive, the
    /// multi-phase transaction otherwise.
    fn begin_resize(&mut self, s: usize, slot: usize, id: JobId, to: usize, expand: bool) {
        let began = self.now;
        let (from, epoch) = {
            let j = &mut self.shards[s].sims[slot];
            let from = j.procs;
            j.running = false;
            j.epoch += 1;
            (from, j.epoch)
        };
        self.launch_resize(s, slot, id, to, from, expand, began, epoch);
    }

    /// Schedule the commit — or the phase chain — of a resize the RMS has
    /// already granted.  The sim must be paused with `epoch` current.
    #[allow(clippy::too_many_arguments)]
    fn launch_resize(
        &mut self,
        s: usize,
        slot: usize,
        id: JobId,
        to: usize,
        from: usize,
        expand: bool,
        began: Time,
        epoch: u64,
    ) {
        let delta = to.abs_diff(from);
        let sched = self.cfg.costs.action_sched(delta, &mut self.shards[s].rng);
        let transfer = self
            .cfg
            .costs
            .resize_transfer(self.cfg.exec.resize_bytes, from, to);
        if !self.shards[s].resize_active {
            // Legacy single-event path: byte-identical to the
            // pre-transaction engine when the fault spec is inactive.
            self.push(
                self.now + sched + transfer,
                s,
                id,
                epoch,
                EvKind::ResizeDone { to, expand, began },
            );
            return;
        }
        // Multi-phase transaction: grant → spawn → redistribute → commit,
        // with this transaction's fault outcomes pre-drawn from the
        // dedicated stream (always exactly three draws, so the stream
        // position is a pure function of the transaction count).
        let grant_at = self.now + sched * self.cfg.costs.grant_frac;
        let spawn_at = self.now + sched;
        let commit_at = self.now + sched + transfer;
        let sh = &mut self.shards[s];
        let fails = sh.resize_faults.draw(&mut sh.resize_rng);
        sh.stats.resize_attempts += 1;
        sh.rms
            .log
            .push(crate::rms::RmsEvent::ResizeBegin { job: id, time: self.now, from, to });
        sh.sims[slot].txn = Some(ResizeTxn { to, from, expand, began, fails, spawn_at, commit_at });
        self.push(grant_at, s, id, epoch, EvKind::ResizePhase { step: resize::PHASE_GRANT });
    }

    /// One phase boundary of an active transaction: the phase either
    /// failed (roll back, then retry with backoff — or degrade) or
    /// completed (advance the chain; the last phase commits).
    fn on_resize_phase(&mut self, ev: Ev, step: u8) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch {
            return;
        }
        let Some(txn) = self.shards[s].sims[slot].txn else {
            return; // defensive: transaction already resolved
        };
        if txn.fails[step as usize] {
            self.abort_txn(s, slot, ev.job, txn, step);
            return;
        }
        match step {
            resize::PHASE_GRANT => self.push(
                txn.spawn_at,
                s,
                ev.job,
                ev.epoch,
                EvKind::ResizePhase { step: resize::PHASE_SPAWN },
            ),
            resize::PHASE_SPAWN => self.push(
                txn.commit_at,
                s,
                ev.job,
                ev.epoch,
                EvKind::ResizePhase { step: resize::PHASE_REDIST },
            ),
            _ => {
                // Redistribution survived: commit the transaction.  The
                // fault-free timing matches the legacy path exactly
                // (grant + spawn = action_sched, redistribute = transfer).
                self.shards[s].sims[slot].txn = None;
                self.shards[s].sims[slot].resize_attempt = 0;
                if txn.expand {
                    self.shards[s].rms.commit_resize(ev.job, self.now);
                    self.actions.expand.push(self.now - txn.began);
                } else {
                    self.shards[s].rms.commit_shrink_to(ev.job, txn.to, self.now);
                    self.actions.shrink.push(self.now - txn.began);
                }
                self.shards[s].rms.log.push(crate::rms::RmsEvent::ResizeCommit {
                    job: ev.job,
                    time: self.now,
                    procs: txn.to,
                });
                self.shards[s].sims[slot].procs = txn.to;
                self.resume_sim(s, slot, ev.job);
                // A shrink may let queued jobs start.
                self.try_schedule(s);
            }
        }
    }

    /// Roll an aborted transaction back to the pre-transaction process
    /// set, then retry after a bounded exponential backoff — or, when the
    /// retry budget is exhausted, degrade the job to non-malleable.
    fn abort_txn(&mut self, s: usize, slot: usize, id: JobId, txn: ResizeTxn, phase: u8) {
        self.shards[s].sims[slot].txn = None;
        self.shards[s].sims[slot].pending_async = None;
        self.shards[s].stats.resize_aborts += 1;
        if txn.expand {
            self.shards[s].rms.abort_expand_to(id, txn.from, self.now, phase);
        } else {
            self.shards[s].rms.abort_shrink(id, self.now, phase);
        }
        let wasted = self.now - txn.began;
        let attempt = self.shards[s].sims[slot].resize_attempt + 1;
        self.shards[s].sims[slot].resize_attempt = attempt;
        if attempt > self.shards[s].resize_faults.max_retries {
            // Out of retries: the job keeps running at its old size,
            // non-malleable for the rest of the run — the RMS marks it
            // degraded (every policy sees NoAction) and the sim stops
            // scheduling DMR checks.
            self.shards[s].stats.retry_time += wasted;
            self.shards[s].stats.degraded_jobs += 1;
            self.shards[s].rms.degrade(id, self.now);
            self.shards[s].sims[slot].spec.malleable = false;
        } else {
            // Resume at the old size immediately; the escalated inhibitor
            // holds the next DMR call until the backoff expires.  (A job
            // with a zero sched-period cannot express a future gate — it
            // simply retries at its next natural check.)
            let backoff = self.shards[s].resize_faults.backoff(attempt);
            self.shards[s].stats.retry_time += wasted + backoff;
            let period = self.shards[s].sims[slot].spec.sched_period;
            self.shards[s].sims[slot].inhibitor =
                Inhibitor::restore(period, Some(self.now + backoff - period));
        }
        self.resume_sim(s, slot, id);
        // An aborted expansion released the granted nodes.
        self.try_schedule(s);
    }

    fn on_resize_done(&mut self, ev: Ev, to: usize, expand: bool, began: Time) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch {
            return;
        }
        if expand {
            self.shards[s].rms.commit_resize(ev.job, self.now);
            self.actions.expand.push(self.now - began);
        } else {
            self.shards[s].rms.commit_shrink_to(ev.job, to, self.now);
            self.actions.shrink.push(self.now - began);
        }
        self.shards[s].sims[slot].procs = to;
        self.resume_sim(s, slot, ev.job);
        // A shrink may let queued jobs start.
        self.try_schedule(s);
    }

    fn on_expand_retry(&mut self, ev: Ev, to: usize, began: Time, deadline: Time) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch {
            return;
        }
        match self.shards[s].rms.dmr_apply(ev.job, Action::Expand { to }, self.now) {
            Ok(DmrOutcome::Expand { .. }) => {
                // Resources appeared: pay the protocol costs now; the
                // elapsed wait is part of the measured expand time.
                let (from, epoch) = {
                    let j = &mut self.shards[s].sims[slot];
                    j.epoch += 1;
                    (j.procs, j.epoch)
                };
                self.launch_resize(s, slot, ev.job, to, from, true, began, epoch);
            }
            _ => {
                if self.now + 1.0 <= deadline {
                    let epoch = ev.epoch;
                    self.push(
                        self.now + 1.0,
                        s,
                        ev.job,
                        epoch,
                        EvKind::ExpandRetry { to, began, deadline },
                    );
                } else {
                    // Timed out: abort the action and resume (§5.2.1).
                    self.actions.expand.push(self.now - began);
                    self.actions.expand_aborts += 1;
                    self.resume_sim(s, slot, ev.job);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Machine events (crate::resilience)

    fn on_node_fail(&mut self, s: usize, node: NodeId, auto: bool) {
        // Keep the per-node failure cycle alive *first*: the repair and
        // next-failure delays are drawn from the shard's dedicated fault
        // stream unconditionally, so each shard's machine timeline is a
        // pure function of (fault spec, seed, shard id) — identical
        // across scheduling modes and routing policies.
        if auto {
            let sh = &mut self.shards[s];
            let (repair_after, next_fail_after) = sh.faults.next_cycle(&mut sh.fault_rng);
            let up_at = self.now + repair_after;
            self.push(up_at, s, 0, 0, EvKind::NodeRepair { node });
            self.push(up_at + next_fail_after, s, 0, 0, EvKind::NodeFail { node, auto: true });
        }
        // Every hardware failure counts and is logged — including one that
        // lands on a node already offline (drain overlap / nested
        // outages).  Both the count and the NodeFailed sequence are then
        // mode-independent, whatever the node happened to be doing.
        self.shards[s].stats.node_failures += 1;
        self.shards[s].fail_depth[node] += 1;
        if matches!(self.shards[s].rms.cluster.state(node), NodeState::Down) {
            // Capacity already gone; the outage is extended (fail_depth),
            // not duplicated, and there is no victim.
            self.shards[s]
                .rms
                .log
                .push(crate::rms::RmsEvent::NodeFailed { node, time: self.now });
            return;
        }
        // Jobs started inside an undrained RMS pass need their sims
        // before the victim lookup.
        self.drain_started(s);
        if let Some(victim) = self.shards[s].rms.fail_node(node, self.now) {
            self.on_job_hit(s, victim.job, victim.survivors, false);
        }
    }

    fn on_node_repair(&mut self, s: usize, node: NodeId) {
        // Outages nest: the node returns only once every failure that hit
        // it has been repaired (a scripted failure without `repair_at`
        // never is — its depth contribution outlives every chain repair).
        if self.shards[s].fail_depth[node] > 0 {
            self.shards[s].fail_depth[node] -= 1;
        }
        // A node under an active drain window stays offline until the
        // window ends.
        if self.shards[s].fail_depth[node] == 0
            && self.shards[s].drain_depth[node] == 0
            && self.shards[s].rms.repair_node(node, self.now)
        {
            self.try_schedule(s);
        }
    }

    fn on_drain_start(&mut self, s: usize, w: usize) {
        let nodes = std::mem::take(&mut self.shards[s].drain_nodes[w]);
        for &n in &nodes {
            self.shards[s].drain_depth[n] += 1;
            if self.shards[s].drain_depth[n] == 1 {
                self.shards[s].rms.begin_drain(n, self.now);
            }
        }
        self.shards[s].drain_nodes[w] = nodes;
    }

    fn on_drain_end(&mut self, s: usize, w: usize) {
        let nodes = std::mem::take(&mut self.shards[s].drain_nodes[w]);
        let mut freed = false;
        for &n in &nodes {
            if self.shards[s].drain_depth[n] > 0 {
                self.shards[s].drain_depth[n] -= 1;
            }
            if self.shards[s].drain_depth[n] == 0 && self.shards[s].fail_depth[n] == 0 {
                freed |= self.shards[s].rms.end_drain(n, self.now);
            }
        }
        self.shards[s].drain_nodes[w] = nodes;
        if freed {
            self.try_schedule(s);
        }
    }

    // ------------------------------------------------------------------
    // Correlated outages + partitions (shard-level failure domains)

    /// A correlated outage takes failure domain `dom` of shard `s` dark:
    /// every domain node force-downs atomically (nesting with node faults
    /// and drains via `fail_depth`), then each interrupted job is
    /// recovered exactly once — rescue-shrink first, cross-shard
    /// evacuation second, local kill + requeue last.
    fn on_outage_start(&mut self, s: usize, dom: usize, auto: bool) {
        // Keep the per-domain outage cycle alive *first* (mirroring
        // `on_node_fail`): duration and next-outage delays are drawn from
        // the dedicated domain stream unconditionally, so each shard's
        // outage timeline is a pure function of (spec, seed, shard id) —
        // identical across scheduling modes and routing policies.
        if auto {
            let sh = &mut self.shards[s];
            let (duration, next_after) = sh.outages.next_cycle(&mut sh.outage_rng);
            let up_at = self.now + duration;
            self.push(up_at, s, 0, 0, EvKind::OutageEnd { dom });
            self.push(up_at + next_after, s, 0, 0, EvKind::OutageStart { dom, auto: true });
        }
        self.shards[s].outage_depth += 1;
        self.shards[s]
            .rms
            .log
            .push(crate::rms::RmsEvent::ShardDown { domain: dom, time: self.now });
        // Materialize sims before force-downing, so every active victim
        // has its slab slot (checkpoint state) at the recovery loop.
        self.drain_started(s);
        let nodes = self.shards[s].domain_nodes.get(dom).cloned().unwrap_or_default();
        let mut victims: Vec<JobId> = Vec::new();
        for &n in &nodes {
            // Each downed node counts as a hardware failure, like
            // `on_node_fail` — the ShardDown marker records the
            // correlation on top, not instead.
            self.shards[s].stats.node_failures += 1;
            self.shards[s].fail_depth[n] += 1;
            if matches!(self.shards[s].rms.cluster.state(n), NodeState::Down) {
                self.shards[s]
                    .rms
                    .log
                    .push(crate::rms::RmsEvent::NodeFailed { node: n, time: self.now });
                continue;
            }
            if let Some(victim) = self.shards[s].rms.fail_node(n, self.now) {
                if !victims.contains(&victim.job) {
                    victims.push(victim.job);
                }
            }
        }
        // Recover each victim once, with its survivor count re-read
        // after *all* domain nodes went down — a job spanning several of
        // them is rolled back and rerouted exactly once.
        for job in victims {
            let survivors = self.shards[s].rms.job(job).map_or(0, |j| j.procs());
            self.on_job_hit(s, job, survivors, true);
        }
        self.try_schedule(s);
    }

    /// The outage on domain `dom` of shard `s` ends: its nodes repair,
    /// unless a node fault or drain window still covers them.
    fn on_outage_end(&mut self, s: usize, dom: usize) {
        let nodes = self.shards[s].domain_nodes.get(dom).cloned().unwrap_or_default();
        let mut freed = false;
        for &n in &nodes {
            if self.shards[s].fail_depth[n] > 0 {
                self.shards[s].fail_depth[n] -= 1;
            }
            if self.shards[s].fail_depth[n] == 0
                && self.shards[s].drain_depth[n] == 0
                && self.shards[s].rms.repair_node(n, self.now)
            {
                freed = true;
            }
        }
        if self.shards[s].outage_depth > 0 {
            self.shards[s].outage_depth -= 1;
        }
        self.shards[s]
            .rms
            .log
            .push(crate::rms::RmsEvent::ShardUp { domain: dom, time: self.now });
        if freed {
            self.try_schedule(s);
        }
    }

    /// A partition isolates shard `s`: local execution continues, but the
    /// meta-scheduler stops routing, stealing and evacuating toward it
    /// until the window ends.
    fn on_partition_start(&mut self, s: usize) {
        self.shards[s].partition_depth += 1;
        self.shards[s]
            .rms
            .log
            .push(crate::rms::RmsEvent::PartitionStarted { time: self.now });
    }

    fn on_partition_end(&mut self, s: usize) {
        if self.shards[s].partition_depth > 0 {
            self.shards[s].partition_depth -= 1;
        }
        self.shards[s]
            .rms
            .log
            .push(crate::rms::RmsEvent::PartitionEnded { time: self.now });
    }

    /// A failure took one or more of `job`'s nodes on shard `s`.  Roll
    /// the job back to its last checkpoint, then recover — in preference
    /// order: shrink onto a factor-reachable count of surviving nodes
    /// (malleable rescue), evacuate to a surviving shard (`evac` — set
    /// only by the correlated-outage handler — and malleable), or kill
    /// and requeue locally.
    fn on_job_hit(&mut self, s: usize, job: JobId, survivors: usize, evac: bool) {
        self.shards[s].stats.interrupted += 1;
        let Some(slot) = self.shards[s].slot(job) else {
            // The job started inside an RMS scheduling pass this driver
            // has not drained yet (it sits in `recent_starts` with no sim
            // slot).  It has made no modeled progress — requeue it; the
            // stale start record is skipped by `try_schedule`'s
            // `is_active()` filter and the job starts again later.
            self.shards[s].rms.requeue_after_failure(job, self.now);
            self.shards[s].stats.requeued += 1;
            self.try_schedule(s);
            return;
        };
        self.progress(s, slot);

        // Roll back to the exact state the last checkpoint held (with no
        // checkpointing, `ckpt_*` stay 0 — everything is lost).
        let (lost, committed, factor, min_procs, malleable) = {
            let j = &mut self.shards[s].sims[slot];
            let lost = (j.run_time_acc - j.ckpt_run_time).max(0.0);
            j.iters_done = j.ckpt_iters;
            j.run_time_acc = j.ckpt_run_time;
            (lost, j.procs, j.spec.factor, j.spec.min_procs, j.spec.malleable)
        };
        self.shards[s].stats.rework_time += lost;

        // A machine fault landing on the job's allocation during an
        // active transaction aborts it *explicitly* (digest-covered
        // `ResizeAbort` with the node-fault phase code) instead of being
        // silently absorbed.  The retry attempt is not charged — the
        // resize protocol itself did not fail — and the recovery below
        // (rescue or requeue) supersedes the rollback.
        if let Some(txn) = self.shards[s].sims[slot].txn.take() {
            self.shards[s].stats.resize_aborts += 1;
            self.shards[s].stats.retry_time += self.now - txn.began;
            self.shards[s].rms.log.push(crate::rms::RmsEvent::ResizeAbort {
                job,
                time: self.now,
                phase: resize::PHASE_NODE_FAULT,
            });
        }
        // A failure during an in-flight resize abandons it: the pending
        // ResizeDone (or phase chain) goes stale via the epoch bump
        // below, and the resize is not recorded in ActionStats (the
        // recovery below is the action that actually happened).
        // Feasibility is judged from the *committed* size (the sim's);
        // the cost uses the RMS's actual pre-failure holding, which can
        // be larger mid-expand.
        let target = if self.cfg.resilience.recovery.rescue && malleable {
            feasible_shrink(committed, survivors, factor, min_procs)
        } else {
            None
        };
        match target {
            Some(to) => {
                self.shards[s].rms.rescue_shrink_to(job, to, self.now);
                self.shards[s].stats.rescued += 1;
                let epoch = {
                    let j = &mut self.shards[s].sims[slot];
                    j.procs = to;
                    j.running = false;
                    j.pending_async = None;
                    j.epoch += 1;
                    j.epoch
                };
                // The rescue pays the shrink protocol: scheduling plus the
                // survivor-side redistribution of the dead node's shard.
                let from = survivors + 1;
                let delta = from.abs_diff(to).max(1);
                let sched = self.cfg.costs.action_sched(delta, &mut self.shards[s].rng);
                let transfer =
                    self.cfg.costs.resize_transfer(self.cfg.exec.resize_bytes, from, to);
                self.push(self.now + sched + transfer, s, job, epoch, EvKind::Resume);
            }
            None => {
                if !(evac && malleable && self.try_evacuate(s, job, slot)) {
                    self.shards[s].rms.requeue_after_failure(job, self.now);
                    self.shards[s].stats.requeued += 1;
                    let j = &mut self.shards[s].sims[slot];
                    j.running = false;
                    j.pending_async = None;
                    j.epoch += 1;
                }
            }
        }
        // Freed nodes (released survivors) may admit queued jobs.
        self.try_schedule(s);
    }

    /// Cross-shard failover of an interrupted malleable job: withdraw it
    /// (with its checkpointed progress, already rolled back by the
    /// caller) from shard `s`, route it to a reachable surviving shard,
    /// re-fit it to that shard's width via the normal factor-chain clamp
    /// and re-submit it there with its original submission time — queue
    /// aging carries over, and the paused sim pre-inserted on the target
    /// resumes from the checkpoint instead of from scratch.  Returns
    /// `false` (caller falls back to the local requeue) when no reachable
    /// shard can ever hold the job.
    fn try_evacuate(&mut self, s: usize, job: JobId, slot: usize) -> bool {
        let Some((min_procs, user)) = self
            .shards[s]
            .rms
            .job(job)
            .map(|j| (j.spec.min_procs, j.spec.user))
        else {
            return false;
        };
        let Some(t) = self.route_evac(s, min_procs, user) else { return false };
        let Some((mut spec, submitted)) = self.shards[s].rms.evacuate(job, t, self.now) else {
            return false;
        };
        let (ckpt_run_time, ckpt_iters) = {
            let j = &self.shards[s].sims[slot];
            (j.ckpt_run_time, j.ckpt_iters)
        };
        // The source slot is recycled — stale events for the old id now
        // miss via `slot() == None`, as on terminal completion.
        self.shards[s].free_sim(job);
        self.shards[s].stats.evacuated += 1;
        self.shards[s].evac_out += 1;
        fit_spec(&mut spec, self.shards[t].rms.cluster.total());
        let est = self.cfg.exec.exec_time(&spec, spec.procs) * self.shards[t].inv_speed;
        let mut sp = SimSpec::of(&spec);
        sp.work_per_iter *= self.shards[t].inv_speed;
        let procs = spec.procs;
        let period = sp.sched_period;
        let nid = self.shards[t].rms.submit(spec, submitted);
        self.shards[t].rms.set_expected_end(nid, self.now + est);
        self.shards[t].evac_in += 1;
        // Pre-insert the paused sim holding the rolled-back progress:
        // when the target starts the job, `drain_started`'s restart path
        // resumes it from the checkpoint.  `memo_procs` is poisoned so
        // the first `iter_time` recomputes on the target's speed.
        let sim = SimJob {
            spec: sp,
            procs,
            iters_done: ckpt_iters.min(sp.iterations as f64),
            last_t: self.now,
            running: false,
            epoch: 0,
            inhibitor: Inhibitor::new(period),
            pending_async: None,
            txn: None,
            resize_attempt: 0,
            memo_procs: usize::MAX,
            memo_iter: 0.0,
            run_time_acc: ckpt_run_time,
            ckpt_run_time,
            ckpt_iters,
        };
        self.shards[t].insert_sim(nid, sim);
        self.try_schedule(t);
        true
    }

    /// Pick the surviving shard an evacuated job fails over to, honoring
    /// the configured routing policy among *reachable* candidates (never
    /// the source, never a dark or partitioned shard, and the pool must
    /// fit `min_procs`).  `None` when no such shard exists — the job then
    /// requeues locally and waits out the outage.
    fn route_evac(&mut self, from: usize, min_procs: usize, user: u32) -> Option<usize> {
        let k = self.shards.len();
        let ok = |sh: &Shard| sh.reachable() && min_procs <= sh.rms.cluster.total();
        match self.routing {
            RoutingPolicy::RoundRobin => {
                let mut pick = None;
                for _ in 0..k {
                    let s = self.rr_next % k;
                    self.rr_next = (self.rr_next + 1) % k;
                    if s != from && ok(&self.shards[s]) {
                        pick = Some(s);
                        break;
                    }
                }
                pick
            }
            RoutingPolicy::LeastLoaded => {
                let mut best: Option<(f64, usize)> = None;
                for (i, sh) in self.shards.iter().enumerate() {
                    if i == from || !ok(sh) {
                        continue;
                    }
                    let load = (sh.rms.pending_user_jobs() + sh.rms.running_jobs()) as f64
                        / sh.rms.cluster.total() as f64;
                    let better = match best {
                        Some((b, _)) => load.total_cmp(&b).is_lt(),
                        None => true,
                    };
                    if better {
                        best = Some((load, i));
                    }
                }
                best.map(|(_, i)| i)
            }
            RoutingPolicy::Locality => {
                let home = user as usize % k;
                (0..k)
                    .map(|d| (home + d) % k)
                    .find(|&s| s != from && ok(&self.shards[s]))
            }
        }
    }

    fn on_resume(&mut self, ev: Ev) {
        let s = ev.shard;
        let Some(slot) = self.shards[s].slot(ev.job) else { return };
        if self.shards[s].sims[slot].epoch != ev.epoch {
            return;
        }
        debug_assert!(!self.shards[s].sims[slot].running, "resume of a running job");
        self.resume_sim(s, slot, ev.job);
    }

    fn next_check_time(&mut self, s: usize, slot: usize) -> Time {
        let exec = &self.cfg.exec;
        let j = &mut self.shards[s].sims[slot];
        let iter_t = j.iter_time(exec);
        // Reconfiguring points are iteration boundaries, rate-limited by
        // the checking inhibitor.
        self.now + iter_t.max(j.spec.sched_period).max(1e-3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn single_fixed_job_runs_exact_time() {
        let w = workload::generate(1, 1).as_fixed();
        let spec = &w.jobs[0];
        let want = ExecModel::default().exec_time(spec, spec.procs);
        let r = Engine::new(DesConfig::default()).run(&w, "one");
        let job = r.rms.jobs().next().unwrap();
        let exec = job.exec_time().unwrap();
        assert!((exec - want).abs() < 1e-6, "exec {exec} vs {want}");
        assert_eq!(r.user_jobs, 1);
        assert!(r.events >= 2, "at least arrival + completion");
    }

    #[test]
    fn streamed_run_matches_batch_for_every_window() {
        let w = workload::generate(30, 7);
        let batch = Engine::new(DesConfig::default()).run(&w, "b");
        for window in [1usize, 7, 64, usize::MAX] {
            let mut st = Materialized::from(&w);
            let r = Engine::new(DesConfig::default())
                .run_stream(&mut st, window, "s")
                .unwrap();
            assert_eq!(
                r.makespan.to_bits(),
                batch.makespan.to_bits(),
                "makespan diverged at window {window}"
            );
            assert_eq!(
                r.rms.log.digest(),
                batch.rms.log.digest(),
                "event log diverged at window {window}"
            );
            assert_eq!(r.events, batch.events, "event count diverged at window {window}");
            assert_eq!(r.user_jobs, 30);
        }
    }

    #[test]
    fn slab_slots_are_reclaimed_and_bounded() {
        let w = workload::generate(30, 7);
        let r = Engine::new(DesConfig::default()).run(&w, "slab");
        assert!(r.peak_slab > 0);
        // Fault-free, every slab-resident job holds ≥ 1 node, so the live
        // slab can never exceed the machine — far below the job count on
        // a long-enough workload.
        assert!(
            r.peak_slab <= r.rms.cluster.total(),
            "peak_slab {} exceeds the machine",
            r.peak_slab
        );
    }

    #[test]
    fn fixed_workload_drains_and_is_deterministic() {
        let w = workload::generate(30, 7).as_fixed();
        let a = Engine::new(DesConfig::default()).run(&w, "a");
        let b = Engine::new(DesConfig::default()).run(&w, "b");
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events, "event count is deterministic");
        assert_eq!(a.rms.log.digest(), b.rms.log.digest(), "event logs bit-identical");
        assert_eq!(a.rms.completed_jobs(), 30);
        assert!(a.rms.check_invariants());
    }

    #[test]
    fn flexible_beats_fixed_makespan() {
        let w = workload::generate(30, 7);
        let fixed = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let flex = Engine::new(DesConfig::default()).run(&w, "flexible");
        assert_eq!(flex.rms.completed_jobs(), 30);
        assert!(
            flex.makespan < fixed.makespan,
            "flexible {} !< fixed {}",
            flex.makespan,
            fixed.makespan
        );
        // Reconfigurations actually happened.
        assert!(flex.actions.shrink.count() + flex.actions.expand.count() > 0);
        assert!(flex.rms.check_invariants());
    }

    #[test]
    fn async_mode_drains() {
        let w = workload::generate(20, 9);
        let cfg = DesConfig { mode: SchedMode::Async, ..Default::default() };
        let r = Engine::new(cfg).run(&w, "async");
        assert_eq!(r.rms.completed_jobs(), 20);
        assert!(r.rms.check_invariants());
    }

    #[test]
    fn resize_faults_abort_roll_back_and_degrade() {
        let w = workload::generate(30, 7);
        let mut cfg = DesConfig::default();
        cfg.resilience.resize_faults = ResizeFaultSpec {
            spawn_fail: 1.0,
            max_retries: 1,
            backoff_base: 5.0,
            backoff_cap: 10.0,
            ..Default::default()
        };
        let r = Engine::new(cfg).run(&w, "rf");
        assert_eq!(r.rms.completed_jobs(), 30, "workload drains despite 100% spawn failures");
        assert!(r.resilience.resize_attempts > 0, "transactions were attempted");
        assert_eq!(
            r.resilience.resize_aborts, r.resilience.resize_attempts,
            "every attempt aborts at spawn_fail = 1"
        );
        assert!(r.resilience.degraded_jobs > 0, "retry budgets get exhausted");
        assert!(r.resilience.retry_time > 0.0);
        assert_eq!(r.rms.log.resize_commits(), 0, "nothing ever commits");
        assert_eq!(r.rms.log.resize_aborts() as u64, r.resilience.resize_aborts);
        assert_eq!(r.rms.log.resize_begins() as u64, r.resilience.resize_attempts);
        assert_eq!(r.rms.log.degradations() as u64, r.resilience.degraded_jobs);
        assert!(r.rms.check_invariants());
    }

    #[test]
    fn fault_free_transactions_commit_at_legacy_times() {
        // An *active* spec whose draws never fire still takes the
        // multi-phase path — the makespan must match the legacy engine
        // bit-for-bit (phase durations sum to sched + transfer, and the
        // cost stream is consumed identically).
        let w = workload::generate(30, 7);
        let legacy = Engine::new(DesConfig::default()).run(&w, "legacy");
        let mut cfg = DesConfig::default();
        cfg.resilience.resize_faults =
            ResizeFaultSpec { spawn_fail: f64::MIN_POSITIVE, ..Default::default() };
        let txn = Engine::new(cfg).run(&w, "txn");
        assert!(txn.resilience.resize_attempts > 0);
        assert_eq!(txn.resilience.resize_aborts, 0, "MIN_POSITIVE never fires");
        assert_eq!(
            legacy.makespan.to_bits(),
            txn.makespan.to_bits(),
            "fault-free transactions commit exactly when the legacy resize did"
        );
        assert_eq!(
            txn.rms.log.resize_commits() as u64,
            txn.resilience.resize_attempts,
            "every transaction commits"
        );
        assert!(txn.rms.check_invariants());
    }

    #[test]
    fn shard_salt_is_zero_for_shard_zero_and_distinct() {
        assert_eq!(shard_salt(0), 0, "flat path streams untouched");
        let salts: std::collections::BTreeSet<u64> = (0..64).map(shard_salt).collect();
        assert_eq!(salts.len(), 64, "salts are distinct");
    }
}
