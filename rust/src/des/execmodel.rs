//! Execution-time model for the discrete-event mode.
//!
//! §7.4 of the paper observes the applications "scale linearly" across the
//! evaluated range, so the default model is `iter_time(p) = work / p`.
//! A parallel-efficiency exponent is exposed for the scaling-sensitivity
//! ablation (DESIGN.md §5): `iter_time(p) = work / p^eff`.

use crate::workload::JobSpec;

#[derive(Debug, Clone)]
pub struct ExecModel {
    /// Scaling exponent: 1.0 = linear (paper's regime).
    pub efficiency: f64,
    /// Data redistributed on a resize, per job (bytes).  The FS overhead
    /// study uses 1 GB (§7.3); the throughput workloads carry their state
    /// (we model the same 1 GB order of magnitude).
    pub resize_bytes: f64,
}

impl Default for ExecModel {
    fn default() -> Self {
        ExecModel { efficiency: 1.0, resize_bytes: 1e9 }
    }
}

impl ExecModel {
    /// Seconds per outer-loop iteration at `procs` processes.  The global
    /// `efficiency` knob multiplies the per-app exponent (ablation).
    pub fn iter_time(&self, spec: &JobSpec, procs: usize) -> f64 {
        self.iter_time_raw(spec.work_per_iter(), spec.alpha, procs)
    }

    /// Spec-free variant for callers that pre-extracted the job constants
    /// (the DES keeps them in a copyable per-job record and memoizes the
    /// result per process count).  Bit-identical to [`Self::iter_time`].
    pub fn iter_time_raw(&self, work_per_iter: f64, alpha: f64, procs: usize) -> f64 {
        work_per_iter / (procs as f64).powf(alpha * self.efficiency)
    }

    /// Full execution time at a fixed size.
    pub fn exec_time(&self, spec: &JobSpec, procs: usize) -> f64 {
        spec.iterations as f64 * self.iter_time(spec, procs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::config::AppKind;

    #[test]
    fn follows_app_alpha_by_default() {
        let m = ExecModel::default();
        let s = JobSpec::from_app(AppKind::Cg, "CG".into(), 0.0, 1.0);
        // CG alpha = 0.33: quartering procs costs 4^0.33.
        let want = 4f64.powf(0.33);
        assert!((m.exec_time(&s, 8) / m.exec_time(&s, 32) - want).abs() < 1e-9);
    }

    #[test]
    fn efficiency_knob_scales_alpha() {
        // efficiency = 1/alpha on CG => effectively linear.
        let m = ExecModel { efficiency: 1.0 / 0.33, ..Default::default() };
        let s = JobSpec::from_app(AppKind::Cg, "CG".into(), 0.0, 1.0);
        let speedup = m.exec_time(&s, 8) / m.exec_time(&s, 32);
        assert!((speedup - 4.0).abs() < 1e-6);
    }
}
