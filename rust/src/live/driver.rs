//! The live workload driver: submits jobs at their arrival times, launches
//! started jobs as vmpi rank-thread groups, reacts to completions and
//! resizes.  Wall-clock time (optionally compressed for FS sleeps via
//! `DMR_TIME_SCALE`).

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::job::{app_main, DriverEvent, JobCtx, Origin, SchedMode};
use crate::rms::{Rms, RmsConfig};
use crate::runtime::ComputeHandle;
use crate::vmpi::World;
use crate::workload::JobSpec;
use crate::{JobId, Time};

/// Driver options.
#[derive(Clone)]
pub struct LiveOpts {
    pub rms: RmsConfig,
    pub mode: SchedMode,
    /// Compress arrival gaps by this factor (1.0 = real time).
    pub arrival_scale: f64,
    /// Final-solution probe (see [`JobCtx::probe`]).
    pub probe: Option<mpsc::Sender<(JobId, Vec<f32>)>>,
}

impl Default for LiveOpts {
    fn default() -> Self {
        Self {
            rms: RmsConfig::default(),
            mode: SchedMode::Sync,
            arrival_scale: 1.0,
            probe: None,
        }
    }
}

/// Summary of a finished live run.
pub struct LiveReport {
    pub rms: Arc<Mutex<Rms>>,
    pub makespan: Time,
    pub jobs: usize,
}

/// The live system: RMS + vmpi world + PJRT compute handle.
pub struct LiveDriver {
    pub rms: Arc<Mutex<Rms>>,
    pub world: World,
    compute: ComputeHandle,
    opts: LiveOpts,
    epoch: Instant,
    events_tx: mpsc::Sender<DriverEvent>,
    events_rx: mpsc::Receiver<DriverEvent>,
}

impl LiveDriver {
    pub fn new(opts: LiveOpts, compute: ComputeHandle) -> Self {
        let (events_tx, events_rx) = mpsc::channel();
        LiveDriver {
            rms: Arc::new(Mutex::new(Rms::new(opts.rms.clone()))),
            world: World::new(),
            compute,
            opts,
            epoch: Instant::now(),
            events_tx,
            events_rx,
        }
    }

    pub fn now(&self) -> Time {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Launch a started job as a group of rank threads.
    fn launch(&self, id: JobId, procs: usize, spec: &JobSpec) {
        let ctx = Arc::new(JobCtx {
            job: id,
            app: spec.app,
            spec: spec.clone(),
            rms: Arc::clone(&self.rms),
            world: self.world.clone(),
            compute: self.compute.clone(),
            epoch: self.epoch,
            events: self.events_tx.clone(),
            mode: self.opts.mode,
            probe: self.opts.probe.clone(),
        });
        let ctx2 = Arc::clone(&ctx);
        self.world.spawn(procs, move |ep| {
            app_main(Arc::clone(&ctx2), ep, Origin::Fresh)
        });
    }

    /// Submit the workload at (scaled) arrival times and run to drain.
    pub fn run(&mut self, mut specs: Vec<JobSpec>) -> LiveReport {
        specs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
        let mut spec_of: HashMap<JobId, JobSpec> = HashMap::new();
        let total = specs.len();
        let mut next = 0usize;
        let mut done = 0usize;

        while done < total {
            // Submit everything that has arrived.
            let now = self.now();
            let mut submitted = false;
            while next < total && specs[next].submit_time * self.opts.arrival_scale <= now {
                let spec = specs[next].clone();
                next += 1;
                let mut rms = self.rms.lock().unwrap();
                let id = rms.submit(spec.clone(), now);
                let est = spec.est_duration();
                rms.set_expected_end(id, now + est);
                spec_of.insert(id, spec);
                submitted = true;
            }
            if submitted || done > 0 {
                self.schedule_and_launch(&spec_of);
            }

            // Wait for the next arrival or a job event.
            let wake = if next < total {
                let t = specs[next].submit_time * self.opts.arrival_scale;
                Some((t - self.now()).max(0.0))
            } else {
                None
            };
            let ev = match wake {
                Some(dt) => self
                    .events_rx
                    .recv_timeout(Duration::from_secs_f64(dt.min(0.5).max(1e-3)))
                    .ok(),
                None => self
                    .events_rx
                    .recv_timeout(Duration::from_millis(200))
                    .ok(),
            };
            match ev {
                Some(DriverEvent::JobDone(_id)) => {
                    done += 1;
                    self.schedule_and_launch(&spec_of);
                }
                Some(DriverEvent::Reschedule) => {
                    self.schedule_and_launch(&spec_of);
                }
                None => {}
            }
        }

        LiveReport { rms: Arc::clone(&self.rms), makespan: self.now(), jobs: total }
    }

    fn schedule_and_launch(&self, spec_of: &HashMap<JobId, JobSpec>) {
        let started = {
            let mut rms = self.rms.lock().unwrap();
            let now = self.now();
            rms.schedule(now);
            // Drain *all* unobserved starts: scheduling passes also run
            // inside dmr_check (resizer protocol) on job threads.
            let started = rms.take_recent_starts();
            for s in &started {
                if let Some(spec) = spec_of.get(&s.job) {
                    rms.set_expected_end(s.job, now + spec.est_duration());
                }
            }
            started
        };
        for s in started {
            // Resizer jobs and already-handled ids are not in spec_of.
            if let Some(spec) = spec_of.get(&s.job) {
                self.launch(s.job, s.nodes.len(), spec);
            }
        }
    }
}
