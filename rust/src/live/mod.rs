//! The live execution mode: real rank threads over [`crate::vmpi`], real
//! data redistribution, real PJRT compute through [`crate::runtime`].
//! Used by the examples, the overhead study (Fig. 3) and the end-to-end
//! integration tests; the paper-scale workloads run in [`crate::des`].

mod driver;
mod job;
pub mod overhead;

pub use driver::{LiveDriver, LiveOpts, LiveReport};
pub use job::{app_main, DriverEvent, JobCtx, Origin, SchedMode};
