//! The per-rank application main loop — Listing 3 of the paper, executed
//! by real threads with real data movement and real PJRT compute.
//!
//! Every iteration is a reconfiguring point: rank 0 consults the RMS
//! (through the checking inhibitor), broadcasts the decision, and on a
//! resize the whole process set redistributes its shards to a freshly
//! spawned set (§5.2, §6) and terminates; the new set resumes from the
//! carried iteration cursor.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::apps::config::AppKind;
use crate::apps::state::AppState;
use crate::dmr::{
    expand_dest, merge_rows, shrink_role, split_rows, Decision, Inhibitor,
    ShrinkRole, StateMsg,
};
use crate::rms::{Action, DmrOutcome, DmrRequest, Rms};
use crate::runtime::ComputeHandle;
use crate::vmpi::{Endpoint, GroupId, RecvSelector, World, TAG_ACK, TAG_DECISION, TAG_STATE};
use crate::workload::JobSpec;
use crate::{JobId, Time};

pub use crate::dmr::SchedMode;

/// Events the job threads send back to the driver.
#[derive(Debug)]
pub enum DriverEvent {
    JobDone(JobId),
    /// A resize committed; the driver should run a scheduling pass (a
    /// shrink may have unblocked a queued job).
    Reschedule,
}

/// Everything a rank thread needs; shared per job via Arc.
pub struct JobCtx {
    pub job: JobId,
    pub app: AppKind,
    pub spec: JobSpec,
    pub rms: Arc<Mutex<Rms>>,
    pub world: World,
    pub compute: ComputeHandle,
    pub epoch: Instant,
    pub events: mpsc::Sender<DriverEvent>,
    pub mode: SchedMode,
    /// Test/validation hook: rank 0 sends the gathered final solution
    /// here on completion.
    pub probe: Option<mpsc::Sender<(JobId, Vec<f32>)>>,
}

impl JobCtx {
    pub fn now(&self) -> Time {
        self.epoch.elapsed().as_secs_f64()
    }

    fn req(&self) -> DmrRequest {
        DmrRequest {
            min: self.spec.min_procs,
            max: self.spec.max_procs,
            pref: self.spec.pref_procs,
            factor: self.spec.factor,
        }
    }
}

/// How a rank obtained its state.
pub enum Origin {
    /// Fresh start (initial allocation).
    Fresh,
    /// Spawned by a resize: receive state from the parent group.
    Spawned { parent: GroupId },
}

/// Rank-0 scheduling state carried across resizes.
struct Rank0State {
    inhibitor: Inhibitor,
    /// Async mode: the decision computed at the previous point.
    pending: Option<Action>,
}

/// The rank main function.  `origin` tells whether to build fresh state or
/// receive it from the parent process set.
pub fn app_main(ctx: Arc<JobCtx>, ep: Endpoint, origin: Origin) {
    let rank = ep.rank();
    let size = ep.size();

    // ------------------------------------------------------------------
    // Obtain state (Listing 1's MPI_Comm_get_parent pattern).
    let (mut state, mut iter, mut r0) = match origin {
        Origin::Fresh => (
            AppState::init(ctx.app, rank, size, ctx.spec.work_scale),
            0u32,
            Rank0State {
                inhibitor: Inhibitor::new(ctx.spec.sched_period),
                pending: None,
            },
        ),
        Origin::Spawned { parent } => {
            let msg = ep.recv(RecvSelector::tag(TAG_STATE));
            let sm = StateMsg::decode(&msg.payload)
                .unwrap_or_else(|e| panic!("spawn state transfer from job {parent}: {e}"));
            let state = AppState::from_rows(
                ctx.app,
                rank,
                size,
                sm.data,
                &sm.scalars,
                ctx.spec.work_scale,
            );
            let r0 = Rank0State {
                inhibitor: Inhibitor::restore(
                    ctx.spec.sched_period,
                    if sm.inhibit_last >= 0.0 { Some(sm.inhibit_last) } else { None },
                ),
                pending: None,
            };
            // All state received: detach from the parent group.
            ep.barrier();
            if rank == 0 {
                ctx.world.join_group(parent);
                ctx.world.destroy_group(parent);
            }
            (state, sm.iter, r0)
        }
    };

    // ------------------------------------------------------------------
    // Main loop (Listing 3).
    while iter < ctx.spec.iterations {
        let decision = decide_collectively(&ctx, &ep, iter, &mut r0);
        match decision {
            Decision::Continue => {
                state
                    .step(&ep, &ctx.compute)
                    .unwrap_or_else(|e| panic!("job {} step failed: {e:#}", ctx.job));
                iter += 1;
            }
            Decision::Resize { to, new_group } => {
                perform_resize(&ctx, &ep, to as usize, new_group, iter, &state, &r0);
                return; // old process set terminates (Listing 2 line 22)
            }
            Decision::Stop => return,
        }
    }

    // Completed: gather the solution (collective — doubles as the final
    // barrier), then rank 0 reports to the RMS and driver.
    let solution = state.gather_solution(&ep);
    if rank == 0 {
        let now = ctx.now();
        {
            let mut rms = ctx.rms.lock().unwrap();
            rms.finish(ctx.job, now);
        }
        if let Some(tx) = &ctx.probe {
            let _ = tx.send((ctx.job, solution));
        }
        let _ = ctx.events.send(DriverEvent::JobDone(ctx.job));
    }
}

/// Rank 0 consults the RMS (inhibitor-gated) and broadcasts the decision;
/// other ranks receive it.  On a resize rank 0 also spawns the new group.
fn decide_collectively(
    ctx: &Arc<JobCtx>,
    ep: &Endpoint,
    iter: u32,
    r0: &mut Rank0State,
) -> Decision {
    if ep.rank() != 0 {
        let m = ep.recv(RecvSelector::from_rank(ep.group(), 0, TAG_DECISION));
        return Decision::decode(&m.payload)
            .unwrap_or_else(|e| panic!("decision broadcast from rank 0: {e}"));
    }

    let mut decision = Decision::Continue;
    if ctx.spec.malleable && iter + 1 < ctx.spec.iterations {
        let now = ctx.now();
        if r0.inhibitor.allow(now) {
            let outcome = {
                let mut rms = ctx.rms.lock().unwrap();
                match ctx.mode {
                    SchedMode::Sync => rms.dmr_check(ctx.job, &ctx.req(), now),
                    SchedMode::Async => {
                        // Apply the decision computed at the previous
                        // point; schedule the next one (§5.1).
                        let prev = r0.pending.take();
                        r0.pending = Some(rms.dmr_peek(ctx.job, &ctx.req(), now));
                        match prev {
                            Some(a) => rms
                                .dmr_apply(ctx.job, a, now)
                                // Stale expansion: the resizer job would
                                // wait; live mode aborts immediately.
                                .unwrap_or(DmrOutcome::NoAction),
                            None => DmrOutcome::NoAction,
                        }
                    }
                }
            };
            decision = match outcome {
                DmrOutcome::NoAction => Decision::Continue,
                DmrOutcome::Expand { to, .. } | DmrOutcome::Shrink { to, .. } => {
                    let new_group = spawn_new_set(ctx, ep.group(), to);
                    Decision::Resize { to: to as u32, new_group }
                }
            };
        }
    }
    let payload = decision.encode();
    for r in 1..ep.size() {
        ep.send(r, TAG_DECISION, payload.clone());
    }
    decision
}

/// Spawn the next process set for this job (MPI_Comm_spawn, §3).
fn spawn_new_set(ctx: &Arc<JobCtx>, parent: GroupId, to: usize) -> GroupId {
    let ctx2 = Arc::clone(ctx);
    ctx.world.spawn(to, move |ep| {
        app_main(Arc::clone(&ctx2), ep, Origin::Spawned { parent })
    })
}

/// Execute the redistribution of Listing 3 / Fig. 2 and commit the resize
/// with the RMS.
fn perform_resize(
    ctx: &Arc<JobCtx>,
    ep: &Endpoint,
    to: usize,
    new_group: GroupId,
    iter: u32,
    state: &AppState,
    r0: &Rank0State,
) {
    let from = ep.size();
    let rank = ep.rank();
    let rows = state.to_rows();
    let row_f32s = state.row_f32s();
    let scalars = state.scalars();
    let inhibit_last = r0.inhibitor.last().unwrap_or(-1.0);
    let mk = |data: Vec<f32>| {
        StateMsg { iter, inhibit_last, scalars: scalars.clone(), data }.encode()
    };

    if to > from {
        // ---- Expand (Fig. 2a): partition and send to factor children.
        let factor = to / from;
        assert_eq!(to % from, 0, "expand {from}->{to} not integral");
        let parts = split_rows(&rows, row_f32s, factor);
        for (i, part) in parts.into_iter().enumerate() {
            ep.send_to_group(new_group, expand_dest(rank, factor, i), TAG_STATE, mk(part));
        }
        ep.barrier();
        if rank == 0 {
            let now = ctx.now();
            ctx.rms.lock().unwrap().commit_resize(ctx.job, now);
            let _ = ctx.events.send(DriverEvent::Reschedule);
        }
    } else {
        // ---- Shrink (Fig. 2b / Listing 3): intra-group merge at the
        // receivers, then forward to the new set; every rank ACKs rank 0
        // before its node is released (§5.2.2).
        let factor = from / to;
        assert_eq!(from % to, 0, "shrink {from}->{to} not integral");
        match shrink_role(rank, factor) {
            ShrinkRole::Sender { dst } => {
                ep.send(dst, TAG_STATE, mk(rows));
            }
            ShrinkRole::Receiver { srcs, new_dst } => {
                let mut parts: Vec<Vec<f32>> = Vec::with_capacity(srcs.len() + 1);
                let mut got: Vec<(usize, Vec<f32>)> = srcs
                    .iter()
                    .map(|&s| {
                        let m = ep.recv(RecvSelector::from_rank(ep.group(), s, TAG_STATE));
                        let sm = StateMsg::decode(&m.payload)
                            .unwrap_or_else(|e| panic!("shrink merge from rank {s}: {e}"));
                        (s, sm.data)
                    })
                    .collect();
                got.sort_by_key(|(s, _)| *s);
                for (_, d) in got {
                    parts.push(d);
                }
                parts.push(rows);
                ep.send_to_group(new_group, new_dst, TAG_STATE, mk(merge_rows(parts)));
            }
        }
        // ACK-synchronized release.
        if rank == 0 {
            for _ in 1..from {
                ep.recv(RecvSelector::tag(TAG_ACK));
            }
            let now = ctx.now();
            ctx.rms.lock().unwrap().commit_shrink_to(ctx.job, to, now);
            let _ = ctx.events.send(DriverEvent::Reschedule);
        } else {
            ep.send(0, TAG_ACK, Vec::new());
        }
    }
}
