//! The isolated overhead study of §7.3 (Fig. 3), measured on *our* stack:
//!
//! * **Scheduling time** — wall time of the RMS reconfiguration decision
//!   (the `dmr_check` path, including the resizer-job protocol for
//!   expansions).
//! * **Resize time** — wall time of the data redistribution between real
//!   process sets (threads), moving the configured payload through the
//!   vmpi substrate with the exact Listing 3 patterns.
//!
//! Absolute values differ from the paper's (their scheduling time is a
//! Slurm RPC over a cluster network; their transfers ride InfiniBand) —
//! EXPERIMENTS.md compares the *shapes*.

use std::sync::mpsc;
use std::time::Instant;

use crate::dmr::{
    expand_dest, merge_rows, shrink_role, split_rows, ShrinkRole, StateMsg,
};
use crate::rms::{DmrOutcome, DmrRequest, Rms, RmsConfig};
use crate::vmpi::{RecvSelector, World, TAG_ACK, TAG_STATE};
use crate::workload::JobSpec;

/// One measured reconfiguration.
#[derive(Debug, Clone)]
pub struct OverheadSample {
    pub from: usize,
    pub to: usize,
    pub sched_secs: f64,
    pub resize_secs: f64,
}

/// Measure the RMS scheduling time for a `from -> to` reconfiguration
/// (fresh RMS per repetition, as each FS job in the paper performs one
/// reconfiguration).
pub fn measure_sched(from: usize, to: usize, nodes: usize) -> f64 {
    let mut rms = Rms::new(RmsConfig { nodes, ..Default::default() });
    let mut spec = JobSpec::from_app(crate::apps::config::AppKind::FlexibleSleep, "FS".into(), 0.0, 1.0);
    spec.procs = from;
    spec.min_procs = 1;
    spec.max_procs = from.max(to);
    spec.pref_procs = None;
    let id = rms.submit(spec.clone(), 0.0);
    rms.schedule(0.0);

    // A queued job triggers the shrink path (as in the workload runs).
    if to < from {
        let mut q = spec.clone();
        q.name = "queued".into();
        q.procs = from - to;
        rms.submit(q, 0.5);
    }

    let req = DmrRequest {
        min: if to > from { to } else { 1 },
        max: from.max(to),
        pref: Some(to),
        factor: 2,
    };
    let t0 = Instant::now();
    let out = rms.dmr_check(id, &req, 1.0);
    let dt = t0.elapsed().as_secs_f64();
    match out {
        DmrOutcome::Expand { to: t, .. } => debug_assert_eq!(t, to),
        DmrOutcome::Shrink { to: t, .. } => debug_assert_eq!(t, to),
        DmrOutcome::NoAction => {}
    }
    dt
}

/// Measure the redistribution time of `total_f32s` elements between real
/// thread groups of size `from` and `to` (expand or shrink pattern picked
/// automatically).  Returns seconds from decision broadcast to the last
/// state byte received + ACKs collected.
pub fn measure_resize(from: usize, to: usize, total_f32s: usize) -> f64 {
    assert!(from != to);
    let world = World::new();
    let row = 1usize;
    let per_old = total_f32s / from;
    let (done_tx, done_rx) = mpsc::channel::<()>();

    // New group: each rank waits for its state message.
    let new_gid = world.spawn(to, move |ep| {
        let m = ep.recv(RecvSelector::tag(TAG_STATE));
        let sm = StateMsg::decode(&m.payload).expect("overhead state transfer decodes");
        std::hint::black_box(&sm.data);
        ep.barrier();
        if ep.rank() == 0 {
            done_tx.send(()).unwrap();
        }
    });

    let t0 = Instant::now();
    // Old group: run the exact Listing 3 redistribution.
    let old_gid = world.spawn(from, move |ep| {
        let rank = ep.rank();
        let data: Vec<f32> = vec![rank as f32; per_old];
        let mk = |d: Vec<f32>| {
            StateMsg { iter: 1, inhibit_last: 0.0, scalars: vec![], data: d }.encode()
        };
        if to > from {
            let factor = to / from;
            let parts = split_rows(&data, row, factor);
            for (i, p) in parts.into_iter().enumerate() {
                ep.send_to_group(new_gid, expand_dest(rank, factor, i), TAG_STATE, mk(p));
            }
        } else {
            let factor = from / to;
            match shrink_role(rank, factor) {
                ShrinkRole::Sender { dst } => {
                    ep.send(dst, TAG_STATE, mk(data));
                }
                ShrinkRole::Receiver { srcs, new_dst } => {
                    let mut parts: Vec<Vec<f32>> = Vec::with_capacity(srcs.len() + 1);
                    for s in srcs {
                        let m = ep.recv(RecvSelector::from_rank(ep.group(), s, TAG_STATE));
                        let sm = StateMsg::decode(&m.payload)
                            .expect("overhead shrink merge decodes");
                        parts.push(sm.data);
                    }
                    parts.push(data);
                    ep.send_to_group(new_gid, new_dst, TAG_STATE, mk(merge_rows(parts)));
                }
            }
            // ACK-synchronized release (§5.2.2).
            if rank == 0 {
                for _ in 1..from {
                    ep.recv(RecvSelector::tag(TAG_ACK));
                }
            } else {
                ep.send(0, TAG_ACK, Vec::new());
            }
        }
    });

    done_rx.recv().expect("resize never completed");
    let dt = t0.elapsed().as_secs_f64();
    world.join_group(old_gid);
    world.join_group(new_gid);
    world.destroy_group(old_gid);
    world.destroy_group(new_gid);
    dt
}

/// The Fig. 3 sweep: factor-2 reconfigurations 1<->2 ... 32<->64, `reps`
/// repetitions each, over `total_f32s` elements of payload.
pub fn fig3_sweep(reps: usize, total_f32s: usize) -> Vec<OverheadSample> {
    let mut out = Vec::new();
    let pairs: Vec<(usize, usize)> = (0..6).map(|k| (1usize << k, 1usize << (k + 1))).collect();
    // expansions (top half of the paper's chart), then shrinks
    for &(a, b) in &pairs {
        for dir in [(a, b), (b, a)] {
            let (from, to) = dir;
            let mut sched = 0.0;
            let mut resize = 0.0;
            for _ in 0..reps {
                sched += measure_sched(from, to, 128);
                resize += measure_resize(from, to, total_f32s);
            }
            out.push(OverheadSample {
                from,
                to,
                sched_secs: sched / reps as f64,
                resize_secs: resize / reps as f64,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sched_measures_positive() {
        let s = measure_sched(4, 8, 32);
        assert!(s > 0.0 && s < 1.0);
        let s = measure_sched(8, 4, 32);
        assert!(s > 0.0 && s < 1.0);
    }

    #[test]
    fn resize_expand_and_shrink_complete() {
        let t = measure_resize(2, 4, 1 << 16);
        assert!(t > 0.0 && t < 5.0);
        let t = measure_resize(4, 2, 1 << 16);
        assert!(t > 0.0 && t < 5.0);
    }

    #[test]
    fn small_sweep_runs() {
        let samples = fig3_sweep(1, 1 << 14);
        assert_eq!(samples.len(), 12);
        assert!(samples.iter().all(|s| s.resize_secs > 0.0));
    }
}
