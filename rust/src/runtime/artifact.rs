//! Artifact registry: the AOT outputs of `python/compile/aot.py`
//! (`<fn>_p<P>.hlo.txt` + `manifest.json`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Input/output spec of one artifact (from the manifest).
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The registry of available artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    dir: PathBuf,
    entries: BTreeMap<String, ArtifactInfo>,
}

fn parse_specs(j: &Json) -> Result<Vec<IoSpec>> {
    let arr = j.as_arr().context("spec list not an array")?;
    arr.iter()
        .map(|s| {
            let shape = s
                .get("shape")
                .and_then(|x| x.as_arr())
                .context("missing shape")?
                .iter()
                .map(|d| d.as_usize().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = s
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or("float32")
                .to_string();
            Ok(IoSpec { shape, dtype })
        })
        .collect()
}

impl ArtifactStore {
    /// Load the manifest from `dir` (default: `$DMR_ARTIFACTS` or
    /// `artifacts/` relative to the workspace root).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?} (run `make artifacts`)"))?;
        let man = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let obj = man.as_obj().context("manifest not an object")?;
        let mut entries = BTreeMap::new();
        for (name, entry) in obj {
            let info = ArtifactInfo {
                name: name.clone(),
                path: dir.join(format!("{name}.hlo.txt")),
                inputs: parse_specs(entry.get("inputs").context("missing inputs")?)?,
                outputs: parse_specs(entry.get("outputs").context("missing outputs")?)?,
            };
            entries.insert(name.clone(), info);
        }
        Ok(ArtifactStore { dir, entries })
    }

    /// Default location: `$DMR_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("DMR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        match self.entries.get(name) {
            Some(i) => Ok(i),
            None => bail!("unknown artifact {name:?} (have: {:?})",
                self.entries.keys().take(8).collect::<Vec<_>>()),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_store() -> (tempdir::TempDirLike, ArtifactStore) {
        let dir = tempdir::TempDirLike::new("dmr_artifact_test");
        let manifest = r#"{"toy_p2": {"inputs": [{"shape": [8], "dtype": "float32"}],
                           "outputs": [{"shape": [8], "dtype": "float32"}]}}"#;
        let mut f = std::fs::File::create(dir.path().join("manifest.json")).unwrap();
        f.write_all(manifest.as_bytes()).unwrap();
        let store = ArtifactStore::open(dir.path()).unwrap();
        (dir, store)
    }

    // Minimal tempdir helper (offline: no tempfile crate).
    mod tempdir {
        pub struct TempDirLike(std::path::PathBuf);
        impl TempDirLike {
            pub fn new(prefix: &str) -> Self {
                let p = std::env::temp_dir().join(format!(
                    "{prefix}_{}_{:?}",
                    std::process::id(),
                    std::thread::current().id()
                ));
                std::fs::create_dir_all(&p).unwrap();
                TempDirLike(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDirLike {
            fn drop(&mut self) {
                std::fs::remove_dir_all(&self.0).ok();
            }
        }
    }

    #[test]
    fn opens_and_lists() {
        let (_d, store) = fake_store();
        assert_eq!(store.len(), 1);
        let info = store.get("toy_p2").unwrap();
        assert_eq!(info.inputs[0].shape, vec![8]);
        assert!(store.get("nope").is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        let r = ArtifactStore::open("/nonexistent/dir");
        assert!(r.is_err());
    }
}
