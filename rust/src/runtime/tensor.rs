//! Host-side tensors that cross the compute-server channel (PJRT types are
//! not `Send`; plain buffers are).

/// A dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        TensorF32 { shape, data }
    }

    pub fn vec(data: Vec<f32>) -> Self {
        let n = data.len();
        TensorF32 { shape: vec![n], data }
    }

    /// A (1,)-shaped "scalar" (the models take scalars as `f32[1]`).
    pub fn scalar(x: f32) -> Self {
        TensorF32 { shape: vec![1], data: vec![x] }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        TensorF32 { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// First element (for (1,)-shaped reduction outputs).
    pub fn item(&self) -> f32 {
        self.data[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(TensorF32::scalar(2.5).item(), 2.5);
        assert_eq!(TensorF32::vec(vec![1.0, 2.0]).shape, vec![2]);
        assert_eq!(TensorF32::zeros(vec![4, 2]).numel(), 8);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn shape_mismatch_panics() {
        TensorF32::new(vec![2, 2], vec![0.0; 3]);
    }
}
