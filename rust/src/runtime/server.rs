//! The compute server: a dedicated thread owning the PJRT client and the
//! compiled executables.
//!
//! The `xla` crate's `PjRtClient` / `Literal` wrap raw C++ pointers behind
//! `Rc` — they are not `Send` — so all PJRT interaction is confined to one
//! thread; rank threads talk to it through a channel carrying plain
//! [`TensorF32`] buffers.  On this 1-core testbed the serialization costs
//! nothing; on a larger machine one server per NUMA domain would be the
//! natural extension.
//!
//! Executables are compiled lazily on first use and cached for the process
//! lifetime (one compiled executable per model variant, as the
//! architecture requires).
//!
//! The `xla` crate is an external (network) dependency, so everything that
//! touches it is gated behind the `pjrt` cargo feature.  Without the
//! feature the server thread reports PJRT as unavailable at startup;
//! `ComputeServer::start` then fails cleanly and the live/artifact
//! integration tests skip (the DES and campaign stacks never need it).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::sync::mpsc;
#[cfg(feature = "pjrt")]
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::artifact::ArtifactStore;
use super::tensor::TensorF32;

// Without the pjrt feature the fallback loop never reads requests, so the
// variant fields are write-only there.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
enum Request {
    Execute {
        artifact: String,
        inputs: Vec<TensorF32>,
        reply: mpsc::Sender<Result<Vec<TensorF32>>>,
    },
    /// Compile without executing (warm-up).
    Warm {
        artifact: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Stats {
        reply: mpsc::Sender<Vec<ExecStat>>,
    },
}

/// Per-artifact execution statistics (perf reporting).
#[derive(Debug, Clone)]
pub struct ExecStat {
    pub artifact: String,
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Cloneable, `Send` handle to the compute server.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<Request>,
}

impl ComputeHandle {
    /// Execute `artifact` with `inputs`; blocks until the result is ready.
    pub fn execute(&self, artifact: &str, inputs: Vec<TensorF32>) -> Result<Vec<TensorF32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("compute server gone"))?;
        rx.recv().map_err(|_| anyhow!("compute server dropped reply"))?
    }

    /// Compile an artifact ahead of time.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("compute server gone"))?;
        rx.recv().map_err(|_| anyhow!("compute server dropped reply"))?
    }

    pub fn stats(&self) -> Vec<ExecStat> {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Request::Stats { reply }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }
}

/// The compute server; keep it alive for the duration of the run.  All
/// handles become inert once this is dropped and the thread drains.
pub struct ComputeServer {
    handle: ComputeHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ComputeServer {
    /// Start the server thread over the given artifact store.
    pub fn start(store: ArtifactStore) -> Result<ComputeServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || server_loop(store, rx, ready_tx))
            .context("spawn compute server")?;
        // Fail fast if the PJRT client cannot start.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("compute server died during startup"))??;
        Ok(ComputeServer { handle: ComputeHandle { tx }, thread: Some(thread) })
    }

    /// Start over the default artifact directory.
    pub fn start_default() -> Result<ComputeServer> {
        Self::start(ArtifactStore::open_default()?)
    }

    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }
}

impl Drop for ComputeServer {
    fn drop(&mut self) {
        // Close our sender so the loop drains and exits...
        let (dead_tx, _) = mpsc::channel();
        self.handle = ComputeHandle { tx: dead_tx };
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    stat: ExecStat,
}

/// Fallback server loop for builds without the `pjrt` feature: refuse to
/// start so callers fail fast with an actionable message.
#[cfg(not(feature = "pjrt"))]
fn server_loop(
    _store: ArtifactStore,
    _rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let _ = ready.send(Err(anyhow!(
        "PJRT backend unavailable: built without the `pjrt` cargo feature \
         (see Cargo.toml; the DES/campaign paths do not need it)"
    )));
}

#[cfg(feature = "pjrt")]
fn server_loop(store: ArtifactStore, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let store = Arc::new(store);
    let mut cache: HashMap<String, Compiled> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { artifact, inputs, reply } => {
                let r = execute_one(&client, &store, &mut cache, &artifact, inputs);
                let _ = reply.send(r);
            }
            Request::Warm { artifact, reply } => {
                let r = compile_one(&client, &store, &mut cache, &artifact).map(|_| ());
                let _ = reply.send(r);
            }
            Request::Stats { reply } => {
                let stats = cache.values().map(|c| c.stat.clone()).collect();
                let _ = reply.send(stats);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn compile_one<'a>(
    client: &xla::PjRtClient,
    store: &ArtifactStore,
    cache: &'a mut HashMap<String, Compiled>,
    artifact: &str,
) -> Result<&'a mut Compiled> {
    if !cache.contains_key(artifact) {
        let info = store.get(artifact)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            info.path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {:?}: {e}", info.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile {artifact}: {e}"))?;
        let compile_secs = t0.elapsed().as_secs_f64();
        cache.insert(
            artifact.to_string(),
            Compiled {
                exe,
                stat: ExecStat {
                    artifact: artifact.to_string(),
                    calls: 0,
                    total_secs: 0.0,
                    compile_secs,
                },
            },
        );
    }
    Ok(cache.get_mut(artifact).unwrap())
}

#[cfg(feature = "pjrt")]
fn execute_one(
    client: &xla::PjRtClient,
    store: &ArtifactStore,
    cache: &mut HashMap<String, Compiled>,
    artifact: &str,
    inputs: Vec<TensorF32>,
) -> Result<Vec<TensorF32>> {
    // Validate against the manifest before crossing into C++.
    {
        let info = store.get(artifact)?;
        if info.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "{artifact}: expected {} inputs, got {}",
                info.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (spec, t)) in info.inputs.iter().zip(&inputs).enumerate() {
            if spec.shape != t.shape {
                return Err(anyhow!(
                    "{artifact}: input {i} shape {:?} != expected {:?}",
                    t.shape,
                    spec.shape
                ));
            }
        }
    }
    let out_shapes: Vec<Vec<usize>> = store.get(artifact)?.outputs.iter().map(|o| o.shape.clone()).collect();

    let compiled = compile_one(client, store, cache, artifact)?;
    let t0 = std::time::Instant::now();

    let lits: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            let l = xla::Literal::vec1(&t.data);
            if t.shape.len() == 1 {
                Ok(l)
            } else {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                l.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
            }
        })
        .collect::<Result<_>>()?;

    let result = compiled
        .exe
        .execute::<xla::Literal>(&lits)
        .map_err(|e| anyhow!("execute {artifact}: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal {artifact}: {e}"))?;
    // aot.py lowers with return_tuple=True: always a tuple.
    let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple {artifact}: {e}"))?;
    let mut out = Vec::with_capacity(parts.len());
    for (i, p) in parts.into_iter().enumerate() {
        let data: Vec<f32> = p.to_vec().map_err(|e| anyhow!("to_vec {artifact}[{i}]: {e}"))?;
        let shape = out_shapes.get(i).cloned().unwrap_or_else(|| vec![data.len()]);
        out.push(TensorF32::new(shape, data));
    }

    compiled.stat.calls += 1;
    compiled.stat.total_secs += t0.elapsed().as_secs_f64();
    Ok(out)
}
