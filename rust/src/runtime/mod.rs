//! The PJRT bridge: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//! Python never runs at job time — `make artifacts` is the only compile
//! step (§DESIGN.md "Three-layer architecture").

mod artifact;
mod server;
mod tensor;

pub use artifact::{ArtifactInfo, ArtifactStore, IoSpec};
pub use server::{ComputeHandle, ComputeServer, ExecStat};
pub use tensor::TensorF32;
