//! Measurement extraction and report emitters for every table and figure
//! of the paper's evaluation (§7).  See DESIGN.md §4 for the experiment
//! index.

pub mod record;
pub mod report;
pub mod summary;

pub use record::{extract, JobRecord, MetricsFold};
pub use summary::{jain_index, FedSummary, RunSummary, ShardSummary};
