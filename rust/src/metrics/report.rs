//! Report emitters: one function per paper table/figure.  Each renders an
//! ASCII artifact (printed by the benches / CLI) and returns CSV rows for
//! `results/`.

use super::summary::RunSummary;
use crate::des::ActionStats;
use crate::util::plot::{bar_chart, step_chart};
use crate::util::stats::Summary;
use crate::util::table::Table;

fn fmt(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Table 2: analysis of the actions performed by the framework
/// (sync vs async) in a 400-job workload.
pub fn table2(sync: &ActionStats, asy: &ActionStats, jobs: usize) -> Table {
    let mut t = Table::new(vec!["Action", "Measure", "Synchronous", "Asynchronous"])
        .with_title("Table 2: actions performed by the framework (400-job workload)");
    let sect = |t: &mut Table, name: &str, s: &Summary, a: &Summary| {
        t.row(vec![name.into(), "Minimum Time (s)".into(), fmt(s.min(), 4), fmt(a.min(), 4)]);
        t.row(vec![name.into(), "Maximum Time (s)".into(), fmt(s.max(), 4), fmt(a.max(), 4)]);
        t.row(vec![name.into(), "Average Time (s)".into(), fmt(s.mean(), 4), fmt(a.mean(), 4)]);
        t.row(vec![
            name.into(),
            "Standard Deviation (s)".into(),
            fmt(s.std(), 4),
            fmt(a.std(), 4),
        ]);
        t.row(vec![
            name.into(),
            "Quantity".into(),
            format!("{}", s.count()),
            format!("{}", a.count()),
        ]);
        t.row(vec![
            name.into(),
            "Actions/Job".into(),
            fmt(s.count() as f64 / jobs as f64, 3),
            fmt(a.count() as f64 / jobs as f64, 3),
        ]);
    };
    sect(&mut t, "No Action", &sync.no_action, &asy.no_action);
    sect(&mut t, "Expand", &sync.expand, &asy.expand);
    sect(&mut t, "Shrink", &sync.shrink, &asy.shrink);
    t
}

/// Table 3: cluster and job measures, fixed vs sync vs async.
pub fn table3(fixed: &RunSummary, sync: &RunSummary, asy: &RunSummary) -> Table {
    let mut t = Table::new(vec!["Measure", "", "Fixed", "Synchronous", "Asynchronous"])
        .with_title("Table 3: cluster and job measures of the 400-job workloads");
    t.row(vec![
        "Resources utilization".into(),
        "Avg. (%)".into(),
        fmt(fixed.util_mean * 100.0, 3),
        fmt(sync.util_mean * 100.0, 3),
        fmt(asy.util_mean * 100.0, 3),
    ]);
    t.row(vec![
        "Resources utilization".into(),
        "Std. (%)".into(),
        fmt(fixed.util_std * 100.0, 3),
        fmt(sync.util_std * 100.0, 3),
        fmt(asy.util_std * 100.0, 3),
    ]);
    let (ws, es, cs) = sync.gains_vs(fixed);
    let (wa, ea, ca) = asy.gains_vs(fixed);
    let mut gain = |name: &str, s: &Summary, a: &Summary| {
        t.row(vec![
            name.to_string(),
            "Avg. (%)".into(),
            "-".into(),
            fmt(s.mean(), 3),
            fmt(a.mean(), 3),
        ]);
        t.row(vec![
            name.to_string(),
            "Std. (%)".into(),
            "-".into(),
            fmt(s.std(), 3),
            fmt(a.std(), 3),
        ]);
    };
    gain("Waiting time gain", &ws, &wa);
    gain("Execution time gain", &es, &ea);
    gain("Completion time gain", &cs, &ca);
    t
}

/// Table 4: the summary measures for every workload size.
pub fn table4(rows: &[(usize, RunSummary, RunSummary)]) -> Table {
    let mut t = Table::new(vec![
        "#Jobs",
        "Version",
        "Utilization Rate",
        "Job Waiting Time",
        "Job Execution Time",
        "Job Completion Time",
    ])
    .with_title("Table 4: summary of the averaged measures from all the workloads");
    for (n, fixed, flex) in rows {
        for s in [fixed, flex] {
            t.row(vec![
                format!("{n}"),
                s.label.clone(),
                format!("{:.2} %", s.util_mean * 100.0),
                format!("{:.2} s", s.wait.mean()),
                format!("{:.2} s", s.exec.mean()),
                format!("{:.2} s", s.completion.mean()),
            ]);
        }
    }
    t
}

/// Fig. 4: workload completion times with flexible-gain labels.
pub fn fig4(rows: &[(usize, RunSummary, RunSummary)]) -> String {
    let mut entries = Vec::new();
    for (n, fixed, flex) in rows {
        entries.push((format!("{n} fixed"), fixed.makespan, String::new()));
        let gain = crate::util::stats::gain_pct(fixed.makespan, flex.makespan);
        entries.push((format!("{n} flex"), flex.makespan, format!("(gain {gain:.1}%)")));
    }
    bar_chart("Fig 4: workload execution times (s)", &entries, 50)
}

/// Fig. 5: average waiting times with gain labels.
pub fn fig5(rows: &[(usize, RunSummary, RunSummary)]) -> String {
    let mut entries = Vec::new();
    for (n, fixed, flex) in rows {
        entries.push((format!("{n} fixed"), fixed.wait.mean(), String::new()));
        let gain = crate::util::stats::gain_pct(fixed.wait.mean(), flex.wait.mean());
        entries.push((format!("{n} flex"), flex.wait.mean(), format!("(gain {gain:.1}%)")));
    }
    bar_chart("Fig 5: average job waiting time (s)", &entries, 50)
}

/// Fig. 6: time evolution of one workload (allocated nodes + running jobs
/// on top; completed jobs at the bottom), fixed vs flexible.
pub fn fig6(fixed: &RunSummary, flex: &RunSummary) -> String {
    let mut s = String::new();
    s.push_str(&step_chart(
        "Fig 6 (top): allocated nodes & running jobs",
        &[
            ("alloc-fixed".into(), fixed.alloc_series.clone()),
            ("alloc-flex".into(), flex.alloc_series.clone()),
            ("run-fixed".into(), fixed.running_series.clone()),
            ("run-flex".into(), flex.running_series.clone()),
        ],
        100,
        16,
    ));
    s.push_str(&step_chart(
        "Fig 6 (bottom): completed jobs",
        &[
            ("done-fixed".into(), fixed.completed_series.clone()),
            ("done-flex".into(), flex.completed_series.clone()),
        ],
        100,
        12,
    ));
    s
}

/// Fig. 7 + Fig. 8 data: per-job times (fixed vs flexible matched by
/// name) grouped by application.  Returns CSV rows:
/// app, name, wait_fixed, wait_flex, exec_fixed, exec_flex, d_wait,
/// d_exec, d_completion.
pub fn perjob_rows(fixed: &RunSummary, flex: &RunSummary) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for f in &fixed.jobs {
        if let Some(x) = flex.jobs.iter().find(|x| x.name == f.name) {
            rows.push(vec![
                f.app.name().to_string(),
                f.name.clone(),
                fmt(f.wait(), 1),
                fmt(x.wait(), 1),
                fmt(f.exec(), 1),
                fmt(x.exec(), 1),
                fmt(f.wait() - x.wait(), 1),
                fmt(f.exec() - x.exec(), 1),
                fmt(f.completion() - x.completion(), 1),
            ]);
        }
    }
    rows
}

/// Fig. 7/8 ASCII preview: per-app average exec/wait and deltas.
pub fn fig7_fig8_preview(fixed: &RunSummary, flex: &RunSummary) -> String {
    let mut t = Table::new(vec![
        "App",
        "exec fixed",
        "exec flex",
        "wait fixed",
        "wait flex",
        "Δcompletion (avg)",
    ])
    .with_title("Fig 7/8: per-job times grouped by application (averages)");
    for app in crate::apps::config::AppKind::WORKLOAD_APPS {
        let sel = |s: &RunSummary, f: fn(&super::record::JobRecord) -> f64| {
            Summary::from_iter(s.jobs.iter().filter(|j| j.app == app).map(f))
        };
        let fe = sel(fixed, |j| j.exec());
        let xe = sel(flex, |j| j.exec());
        let fw = sel(fixed, |j| j.wait());
        let xw = sel(flex, |j| j.wait());
        let fc = sel(fixed, |j| j.completion());
        let xc = sel(flex, |j| j.completion());
        t.row(vec![
            app.name().to_string(),
            fmt(fe.mean(), 0),
            fmt(xe.mean(), 0),
            fmt(fw.mean(), 0),
            fmt(xw.mean(), 0),
            fmt(fc.mean() - xc.mean(), 0),
        ]);
    }
    t.render()
}

/// CSV rows for the Table 4 / Fig 4 / Fig 5 sweep.
pub fn throughput_rows(rows: &[(usize, RunSummary, RunSummary)]) -> Vec<Vec<String>> {
    let mut out = Vec::new();
    for (n, fixed, flex) in rows {
        for s in [fixed, flex] {
            out.push(vec![
                n.to_string(),
                s.label.clone(),
                fmt(s.makespan, 1),
                fmt(s.util_mean * 100.0, 2),
                fmt(s.wait.mean(), 1),
                fmt(s.exec.mean(), 1),
                fmt(s.completion.mean(), 1),
                fmt(s.node_seconds(), 0),
            ]);
        }
    }
    out
}

// ------------------------------------------------------------------
// Campaign emitters (see `crate::campaign`): per-run rows, per-scenario
// aggregate rows with 95 % CIs, a console table and a JSON document.
// Kept here so every CSV/JSON artifact the crate produces flows through
// one module.

/// Header of `<name>_runs.csv` — the single source of truth for the
/// per-run column set; [`campaign_run_rows`] emits cells in exactly this
/// order and the header-golden test locks the joined string.  Federation
/// columns sit at the end so flat-campaign consumers parse unchanged
/// prefixes; flat runs fill them with `1` / `-` / `0` placeholders.
pub const CAMPAIGN_RUN_HEADER: &[&str] = &[
    "run", "scenario", "label", "nodes", "mode", "policy", "seed", "jobs", "makespan_s",
    "util_pct", "wait_mean_s", "exec_mean_s", "completion_mean_s", "node_seconds", "expands",
    "shrinks", "expand_aborts", "bounded_slowdown", "jain_fairness", "deadline_jobs",
    "deadline_misses", "interrupted", "rescued", "requeued", "rework_s", "lost_node_s",
    "availability_pct", "fed_shards", "fed_routing", "fed_steals", "shard_util_pct",
    "shard_queue_depth", "shard_steals", "resize_attempts", "resize_aborts", "retry_time_s",
    "degraded_jobs", "sched_passes", "sched_elided", "dmr_checks", "dmr_elided",
    "peak_live_jobs", "shard_jain", "evacuations", "cross_shard_requeues", "shard_avail_pct",
];

/// Header of `<name>_agg.csv` — single source of truth, like
/// [`CAMPAIGN_RUN_HEADER`].
pub const CAMPAIGN_AGG_HEADER: &[&str] = &[
    "scenario", "runs", "jobs", "makespan_mean_s", "makespan_ci95_s", "util_mean_pct",
    "util_ci95_pct", "wait_mean_s", "wait_ci95_s", "exec_mean_s", "exec_ci95_s",
    "completion_mean_s", "completion_ci95_s", "node_seconds_mean", "expands_mean",
    "shrinks_mean", "expand_aborts_mean", "slowdown_mean", "slowdown_ci95", "fairness_mean",
    "fairness_ci95", "deadline_miss_mean", "interrupted_mean", "rescued_mean",
    "requeued_mean", "rework_mean_s", "lost_node_s_mean", "availability_mean_pct",
    "fed_shards", "fed_steals_mean", "shard_util_mean_pct", "resize_attempts_mean",
    "resize_aborts_mean", "retry_time_mean_s", "degraded_jobs_mean", "sched_passes_mean",
    "sched_elided_mean", "dmr_checks_mean", "dmr_elided_mean", "peak_live_mean",
    "shard_jain_mean", "evacuations_mean", "cross_shard_requeues_mean", "shard_avail_mean_pct",
];

/// The per-run CSV columns (accessor over [`CAMPAIGN_RUN_HEADER`] so
/// writers and tests share one definition).
pub fn run_columns() -> &'static [&'static str] {
    CAMPAIGN_RUN_HEADER
}

/// The per-scenario aggregate CSV columns (accessor over
/// [`CAMPAIGN_AGG_HEADER`]).
pub fn agg_columns() -> &'static [&'static str] {
    CAMPAIGN_AGG_HEADER
}

/// One CSV row per campaign run, in matrix order.
pub fn campaign_run_rows(records: &[crate::campaign::RunRecord]) -> Vec<Vec<String>> {
    records
        .iter()
        .map(|r| {
            let s = &r.summary;
            let mut row = vec![
                r.plan.index.to_string(),
                r.plan.scenario.clone(),
                r.plan.label.clone(),
                r.plan.nodes.to_string(),
                r.plan.mode.label().to_string(),
                r.plan.strategy.label().to_string(),
                r.plan.seed.to_string(),
                r.jobs.to_string(),
                fmt(s.makespan, 3),
                fmt(s.util_mean * 100.0, 2),
                fmt(s.wait.mean(), 3),
                fmt(s.exec.mean(), 3),
                fmt(s.completion.mean(), 3),
                fmt(s.node_seconds(), 1),
                s.actions.expand.count().to_string(),
                s.actions.shrink.count().to_string(),
                s.actions.expand_aborts.to_string(),
                fmt(s.bounded_slowdown.mean(), 3),
                fmt(s.fairness_jain, 4),
                s.deadline_jobs.to_string(),
                s.deadline_misses.to_string(),
                s.resilience.interrupted.to_string(),
                s.resilience.rescued.to_string(),
                s.resilience.requeued.to_string(),
                fmt(s.resilience.rework_time, 1),
                fmt(s.resilience.lost_node_seconds, 1),
                fmt(s.resilience.availability * 100.0, 3),
            ];
            match &s.federation {
                Some(f) => {
                    row.push(f.shards.to_string());
                    row.push(f.routing.clone());
                    row.push(f.steals.to_string());
                    row.push(join_shards(&f.per_shard, |sh| fmt(sh.util_pct, 2)));
                    row.push(join_shards(&f.per_shard, |sh| fmt(sh.queue_depth, 2)));
                    row.push(join_shards(&f.per_shard, |sh| {
                        format!("{}:{}", sh.steals_in, sh.steals_out)
                    }));
                }
                None => {
                    row.extend(["1", "-", "0", "-", "-", "-"].map(String::from));
                }
            }
            row.push(s.resilience.resize_attempts.to_string());
            row.push(s.resilience.resize_aborts.to_string());
            row.push(fmt(s.resilience.retry_time, 1));
            row.push(s.resilience.degraded_jobs.to_string());
            // Deterministic pass/check counters — never the wall-clock
            // profile, which would break worker-count invariance.
            row.push(s.passes.sched_passes.to_string());
            row.push(s.passes.sched_elided.to_string());
            row.push(s.passes.dmr_checks.to_string());
            row.push(s.passes.dmr_elided.to_string());
            row.push(s.peak_live.to_string());
            // Failure-domain columns (end-appended; flat runs keep the
            // placeholder shape of the other federation columns).
            match &s.federation {
                Some(f) => {
                    row.push(fmt(f.shard_jain, 4));
                    row.push(f.evacuations.to_string());
                    row.push(f.cross_requeues.to_string());
                    row.push(join_shards(&f.per_shard, |sh| {
                        fmt(sh.availability * 100.0, 3)
                    }));
                }
                None => {
                    row.extend(["-", "0", "0", "-"].map(String::from));
                }
            }
            row
        })
        .collect()
}

/// `;`-join one formatted value per shard (shard-id order).
fn join_shards(
    shards: &[crate::metrics::ShardSummary],
    f: impl Fn(&crate::metrics::ShardSummary) -> String,
) -> String {
    shards.iter().map(f).collect::<Vec<_>>().join(";")
}

/// One CSV row per scenario aggregate.
pub fn campaign_agg_rows(aggs: &[crate::campaign::ScenarioAgg]) -> Vec<Vec<String>> {
    aggs.iter()
        .map(|a| {
            let mut row = vec![
                a.scenario.clone(),
                a.runs.to_string(),
                a.jobs.to_string(),
                fmt(a.makespan_s.mean(), 3),
                fmt(a.makespan_s.ci95_half(), 3),
                fmt(a.util_pct.mean(), 2),
                fmt(a.util_pct.ci95_half(), 2),
                fmt(a.wait_s.mean(), 3),
                fmt(a.wait_s.ci95_half(), 3),
                fmt(a.exec_s.mean(), 3),
                fmt(a.exec_s.ci95_half(), 3),
                fmt(a.completion_s.mean(), 3),
                fmt(a.completion_s.ci95_half(), 3),
                fmt(a.node_seconds.mean(), 1),
                fmt(a.expands.mean(), 2),
                fmt(a.shrinks.mean(), 2),
                fmt(a.expand_aborts.mean(), 2),
                fmt(a.slowdown.mean(), 3),
                fmt(a.slowdown.ci95_half(), 3),
                fmt(a.fairness.mean(), 4),
                fmt(a.fairness.ci95_half(), 4),
                fmt(a.deadline_misses.mean(), 2),
                fmt(a.interrupted.mean(), 2),
                fmt(a.rescued.mean(), 2),
                fmt(a.requeued.mean(), 2),
                fmt(a.rework_s.mean(), 1),
                fmt(a.lost_node_s.mean(), 1),
                fmt(a.availability_pct.mean(), 3),
            ];
            row.push(a.fed_shards.to_string());
            row.push(fmt(a.fed_steals.mean(), 2));
            row.push(if a.shard_util.is_empty() {
                "-".to_string()
            } else {
                a.shard_util.iter().map(|s| fmt(s.mean(), 2)).collect::<Vec<_>>().join(";")
            });
            row.push(fmt(a.resize_attempts.mean(), 2));
            row.push(fmt(a.resize_aborts.mean(), 2));
            row.push(fmt(a.retry_time_s.mean(), 1));
            row.push(fmt(a.degraded_jobs.mean(), 2));
            row.push(fmt(a.sched_passes.mean(), 1));
            row.push(fmt(a.sched_elided.mean(), 1));
            row.push(fmt(a.dmr_checks.mean(), 1));
            row.push(fmt(a.dmr_elided.mean(), 1));
            row.push(fmt(a.peak_live.mean(), 1));
            row.push(if a.shard_jain.count() == 0 {
                "-".to_string()
            } else {
                fmt(a.shard_jain.mean(), 4)
            });
            row.push(fmt(a.evacuations.mean(), 2));
            row.push(fmt(a.cross_requeues.mean(), 2));
            row.push(if a.shard_avail.is_empty() {
                "-".to_string()
            } else {
                a.shard_avail
                    .iter()
                    .map(|s| fmt(s.mean(), 3))
                    .collect::<Vec<_>>()
                    .join(";")
            });
            row
        })
        .collect()
}

/// Console preview of the aggregates (`mean ± ci95` columns).
pub fn campaign_table(name: &str, aggs: &[crate::campaign::ScenarioAgg]) -> Table {
    let mut t = Table::new(vec![
        "Scenario", "Runs", "Makespan (s)", "Util (%)", "Wait (s)", "Completion (s)",
        "Expands", "Shrinks", "Slowdown", "Jain", "DlMiss", "Rescued", "Requeued",
        "Avail (%)", "Shards", "Steals", "Evac", "Events/s",
    ])
    .with_title(&format!("Campaign {name}: per-scenario aggregates (mean ± 95% CI)"));
    let pm = |s: &Summary, prec: usize| format!("{} ± {}", fmt(s.mean(), prec), fmt(s.ci95_half(), prec));
    for a in aggs {
        t.row(vec![
            a.scenario.clone(),
            a.runs.to_string(),
            pm(&a.makespan_s, 1),
            pm(&a.util_pct, 1),
            pm(&a.wait_s, 1),
            pm(&a.completion_s, 1),
            fmt(a.expands.mean(), 1),
            fmt(a.shrinks.mean(), 1),
            pm(&a.slowdown, 2),
            fmt(a.fairness.mean(), 3),
            fmt(a.deadline_misses.mean(), 1),
            fmt(a.rescued.mean(), 1),
            fmt(a.requeued.mean(), 1),
            fmt(a.availability_pct.mean(), 2),
            a.fed_shards.to_string(),
            fmt(a.fed_steals.mean(), 1),
            fmt(a.evacuations.mean(), 1),
            // Wall-clock throughput: stdout-only (timing noise, never in
            // the CSVs); "-" when nothing was measured.
            if a.wall_ns_total == 0 {
                "-".to_string()
            } else {
                fmt(a.events_total as f64 * 1e9 / a.wall_ns_total as f64, 0)
            },
        ]);
    }
    t
}

/// The aggregate document for `<name>_agg.json`.
pub fn campaign_agg_json(
    spec: &crate::campaign::CampaignSpec,
    aggs: &[crate::campaign::ScenarioAgg],
) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let stat = |s: &Summary| {
        let mut m = BTreeMap::new();
        m.insert("mean".into(), Json::Num(s.mean()));
        m.insert("std".into(), Json::Num(s.sample_std()));
        m.insert("ci95".into(), Json::Num(s.ci95_half()));
        m.insert("min".into(), Json::Num(s.min()));
        m.insert("max".into(), Json::Num(s.max()));
        Json::Obj(m)
    };
    let scenarios: Vec<Json> = aggs
        .iter()
        .map(|a| {
            let mut m = BTreeMap::new();
            m.insert("scenario".into(), Json::Str(a.scenario.clone()));
            m.insert("runs".into(), Json::Num(a.runs as f64));
            m.insert("jobs".into(), Json::Num(a.jobs as f64));
            m.insert("makespan_s".into(), stat(&a.makespan_s));
            m.insert("util_pct".into(), stat(&a.util_pct));
            m.insert("wait_s".into(), stat(&a.wait_s));
            m.insert("exec_s".into(), stat(&a.exec_s));
            m.insert("completion_s".into(), stat(&a.completion_s));
            m.insert("node_seconds".into(), stat(&a.node_seconds));
            m.insert("expands".into(), stat(&a.expands));
            m.insert("shrinks".into(), stat(&a.shrinks));
            m.insert("expand_aborts".into(), stat(&a.expand_aborts));
            m.insert("bounded_slowdown".into(), stat(&a.slowdown));
            m.insert("jain_fairness".into(), stat(&a.fairness));
            m.insert("deadline_misses".into(), stat(&a.deadline_misses));
            m.insert("interrupted".into(), stat(&a.interrupted));
            m.insert("rescued".into(), stat(&a.rescued));
            m.insert("requeued".into(), stat(&a.requeued));
            m.insert("rework_s".into(), stat(&a.rework_s));
            m.insert("lost_node_seconds".into(), stat(&a.lost_node_s));
            m.insert("availability_pct".into(), stat(&a.availability_pct));
            m.insert("resize_attempts".into(), stat(&a.resize_attempts));
            m.insert("resize_aborts".into(), stat(&a.resize_aborts));
            m.insert("retry_time_s".into(), stat(&a.retry_time_s));
            m.insert("degraded_jobs".into(), stat(&a.degraded_jobs));
            m.insert("sched_passes".into(), stat(&a.sched_passes));
            m.insert("sched_elided".into(), stat(&a.sched_elided));
            m.insert("dmr_checks".into(), stat(&a.dmr_checks));
            m.insert("dmr_elided".into(), stat(&a.dmr_elided));
            m.insert("peak_live_jobs".into(), stat(&a.peak_live));
            let mut fed = BTreeMap::new();
            fed.insert("shards".into(), Json::Num(a.fed_shards as f64));
            fed.insert("steals".into(), stat(&a.fed_steals));
            fed.insert(
                "shard_util_mean_pct".into(),
                Json::Arr(a.shard_util.iter().map(|s| Json::Num(s.mean())).collect()),
            );
            fed.insert("shard_jain".into(), stat(&a.shard_jain));
            fed.insert("evacuations".into(), stat(&a.evacuations));
            fed.insert("cross_shard_requeues".into(), stat(&a.cross_requeues));
            fed.insert(
                "shard_avail_mean_pct".into(),
                Json::Arr(a.shard_avail.iter().map(|s| Json::Num(s.mean())).collect()),
            );
            m.insert("federation".into(), Json::Obj(fed));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("campaign".into(), Json::Str(spec.name.clone()));
    root.insert("matrix_size".into(), Json::Num(spec.matrix_size() as f64));
    root.insert("scenarios".into(), Json::Arr(scenarios));
    Json::Obj(root)
}

// ------------------------------------------------------------------
// Perf-bench emitter: the machine-readable trajectory point written by
// `benches/hotpath_scale.rs` (BENCH_hotpath.json).  Kept here so every
// CSV/JSON artifact the crate produces flows through one module.

/// One measured scenario of a perf bench.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Scenario id, e.g. `feitelson5000-n1024-sync`.
    pub scenario: String,
    /// Workload source (`feitelson` | `swf`).
    pub workload: String,
    pub jobs: usize,
    pub nodes: usize,
    pub mode: String,
    /// DES events processed (see [`crate::des::RunResult::events`]).
    pub events: u64,
    /// Wall-clock seconds for the measured run (timing — informational,
    /// never a CI gate).
    pub wall_secs: f64,
    pub makespan_s: f64,
    /// Hex digest over the run's event log and makespan bits.  Identical
    /// re-runs must produce identical checksums — the determinism gate.
    pub checksum: String,
    /// Peak-resident (live) job count of the measured run — the
    /// streaming memory bound (see [`crate::des::RunResult::peak_slab`]).
    pub peak_live: usize,
    /// Wall nanoseconds the engine spent dispatching events (the
    /// self-profile's total; informational, never a CI gate).
    pub dispatch_ns: u64,
    /// Wall nanoseconds inside scheduling passes.
    pub sched_ns: u64,
    /// Wall nanoseconds inside DMR policy evaluations.
    pub dmr_ns: u64,
}

/// Deterministic hex checksum for one run: event-log digest mixed with
/// the makespan bits.
pub fn bench_checksum(log: &crate::rms::EventLog, makespan: f64) -> String {
    let h = log
        .digest()
        .wrapping_mul(0x0000_0100_0000_01B3)
        ^ makespan.to_bits();
    format!("{h:016x}")
}

/// The `BENCH_<name>.json` document: per-scenario events/s plus overall
/// totals (runs/s), designed to be diffed across PRs as the repo's perf
/// trajectory.  Timing fields are informational; checksums are the only
/// values CI asserts on.
pub fn bench_json(bench: &str, records: &[BenchRecord]) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let scenarios: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("scenario".into(), Json::Str(r.scenario.clone()));
            m.insert("workload".into(), Json::Str(r.workload.clone()));
            m.insert("jobs".into(), Json::Num(r.jobs as f64));
            m.insert("nodes".into(), Json::Num(r.nodes as f64));
            m.insert("mode".into(), Json::Str(r.mode.clone()));
            m.insert("events".into(), Json::Num(r.events as f64));
            m.insert("wall_secs".into(), Json::Num(r.wall_secs));
            m.insert(
                "events_per_sec".into(),
                Json::Num(r.events as f64 / r.wall_secs.max(1e-9)),
            );
            m.insert("makespan_s".into(), Json::Num(r.makespan_s));
            m.insert("checksum".into(), Json::Str(r.checksum.clone()));
            m.insert("peak_live_jobs".into(), Json::Num(r.peak_live as f64));
            let mut prof = BTreeMap::new();
            prof.insert("dispatch_ns".into(), Json::Num(r.dispatch_ns as f64));
            prof.insert("sched_ns".into(), Json::Num(r.sched_ns as f64));
            prof.insert("dmr_ns".into(), Json::Num(r.dmr_ns as f64));
            let total = r.dispatch_ns.max(1) as f64;
            prof.insert("sched_share".into(), Json::Num(r.sched_ns as f64 / total));
            prof.insert("dmr_share".into(), Json::Num(r.dmr_ns as f64 / total));
            m.insert("profile".into(), Json::Obj(prof));
            Json::Obj(m)
        })
        .collect();
    let total_events: u64 = records.iter().map(|r| r.events).sum();
    let total_wall: f64 = records.iter().map(|r| r.wall_secs).sum();
    let mut totals = BTreeMap::new();
    totals.insert("runs".into(), Json::Num(records.len() as f64));
    totals.insert("events".into(), Json::Num(total_events as f64));
    totals.insert("wall_secs".into(), Json::Num(total_wall));
    totals.insert(
        "events_per_sec".into(),
        Json::Num(total_events as f64 / total_wall.max(1e-9)),
    );
    totals.insert(
        "runs_per_sec".into(),
        Json::Num(records.len() as f64 / total_wall.max(1e-9)),
    );
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str(bench.to_string()));
    root.insert("schema_version".into(), Json::Num(1.0));
    root.insert("scenarios".into(), Json::Arr(scenarios));
    root.insert("totals".into(), Json::Obj(totals));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Engine};
    use crate::metrics::RunSummary;
    use crate::workload;

    fn pair(n: usize, seed: u64) -> (usize, RunSummary, RunSummary) {
        let w = workload::generate(n, seed);
        let fixed =
            RunSummary::from_run(Engine::new(DesConfig::default()).run(&w.as_fixed(), "Fixed"));
        let flex =
            RunSummary::from_run(Engine::new(DesConfig::default()).run(&w, "Flexible"));
        (n, fixed, flex)
    }

    #[test]
    fn all_reports_render() {
        let p = pair(15, 2);
        let rows = vec![p];
        let t4 = table4(&rows).render();
        assert!(t4.contains("Fixed") && t4.contains("Flexible"));
        let f4 = fig4(&rows);
        assert!(f4.contains("gain"));
        let f5 = fig5(&rows);
        assert!(f5.contains("gain"));
        let (_, fixed, flex) = &rows[0];
        let f6 = fig6(fixed, flex);
        assert!(f6.contains("allocated nodes"));
        let pj = perjob_rows(fixed, flex);
        assert_eq!(pj.len(), 15);
        let prev = fig7_fig8_preview(fixed, flex);
        assert!(prev.contains("CG"));
        let t3 = table3(fixed, flex, flex).render();
        assert!(t3.contains("utilization"));
        let t2 = table2(&fixed.actions, &flex.actions, 15).render();
        assert!(t2.contains("Expand"));
        let tr = throughput_rows(&rows);
        assert_eq!(tr.len(), 2);
    }

    #[test]
    fn campaign_reports_render() {
        let spec = crate::campaign::CampaignSpec::from_toml_str(
            r#"
name = "report-unit"
nodes = [32]
modes = ["fixed", "sync"]
seeds = [1, 2]
[[workload]]
kind = "feitelson"
jobs = 5
"#,
        )
        .unwrap();
        let res = crate::campaign::run_campaign(&spec, 1).unwrap();
        let rows = campaign_run_rows(&res.records);
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.len() == CAMPAIGN_RUN_HEADER.len()));
        let aggs = crate::campaign::aggregate(&res.records);
        let arow = campaign_agg_rows(&aggs);
        assert_eq!(arow.len(), 2);
        assert!(arow.iter().all(|r| r.len() == CAMPAIGN_AGG_HEADER.len()));
        let table = campaign_table("report-unit", &aggs).render();
        assert!(table.contains("±") && table.contains("Scenario"));
        let json = campaign_agg_json(&spec, &aggs).render();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("campaign").unwrap().as_str(), Some("report-unit"));
        assert_eq!(parsed.get("scenarios").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn campaign_headers_are_golden() {
        // The exact joined header strings are a compatibility contract for
        // downstream CSV consumers (CI greps, notebooks).  Any column
        // addition must land at the END of the matching header and update
        // this test deliberately.
        assert_eq!(
            run_columns().join(","),
            "run,scenario,label,nodes,mode,policy,seed,jobs,makespan_s,util_pct,\
             wait_mean_s,exec_mean_s,completion_mean_s,node_seconds,expands,shrinks,\
             expand_aborts,bounded_slowdown,jain_fairness,deadline_jobs,deadline_misses,\
             interrupted,rescued,requeued,rework_s,lost_node_s,availability_pct,\
             fed_shards,fed_routing,fed_steals,shard_util_pct,shard_queue_depth,\
             shard_steals,resize_attempts,resize_aborts,retry_time_s,degraded_jobs,\
             sched_passes,sched_elided,dmr_checks,dmr_elided,peak_live_jobs,\
             shard_jain,evacuations,cross_shard_requeues,shard_avail_pct"
        );
        assert_eq!(
            agg_columns().join(","),
            "scenario,runs,jobs,makespan_mean_s,makespan_ci95_s,util_mean_pct,\
             util_ci95_pct,wait_mean_s,wait_ci95_s,exec_mean_s,exec_ci95_s,\
             completion_mean_s,completion_ci95_s,node_seconds_mean,expands_mean,\
             shrinks_mean,expand_aborts_mean,slowdown_mean,slowdown_ci95,fairness_mean,\
             fairness_ci95,deadline_miss_mean,interrupted_mean,rescued_mean,\
             requeued_mean,rework_mean_s,lost_node_s_mean,availability_mean_pct,\
             fed_shards,fed_steals_mean,shard_util_mean_pct,resize_attempts_mean,\
             resize_aborts_mean,retry_time_mean_s,degraded_jobs_mean,sched_passes_mean,\
             sched_elided_mean,dmr_checks_mean,dmr_elided_mean,peak_live_mean,\
             shard_jain_mean,evacuations_mean,cross_shard_requeues_mean,shard_avail_mean_pct"
        );
        // accessors and consts are the same object
        assert!(std::ptr::eq(run_columns(), CAMPAIGN_RUN_HEADER));
        assert!(std::ptr::eq(agg_columns(), CAMPAIGN_AGG_HEADER));
    }

    #[test]
    fn bench_json_round_trips() {
        let w = workload::generate(10, 3);
        let r = Engine::new(DesConfig::default()).run(&w, "bench-unit");
        let rec = BenchRecord {
            scenario: "feitelson10-n64-sync".into(),
            workload: "feitelson".into(),
            jobs: 10,
            nodes: 64,
            mode: "sync".into(),
            events: r.events,
            wall_secs: 0.25,
            makespan_s: r.makespan,
            checksum: bench_checksum(&r.rms.log, r.makespan),
            peak_live: r.peak_slab,
            dispatch_ns: r.profile.total_ns(),
            sched_ns: r.profile.wall_ns(crate::obs::Phase::Schedule),
            dmr_ns: r.profile.wall_ns(crate::obs::Phase::Dmr),
        };
        // Checksum is a deterministic function of the run.
        assert_eq!(rec.checksum, bench_checksum(&r.rms.log, r.makespan));
        assert_eq!(rec.checksum.len(), 16);

        let doc = bench_json("hotpath_scale", &[rec.clone(), rec.clone()]).render();
        let parsed = crate::util::json::Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("hotpath_scale"));
        let scen = parsed.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scen.len(), 2);
        assert_eq!(scen[0].get("events").unwrap().as_usize(), Some(r.events as usize));
        assert!(scen[0].get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(scen[0].get("peak_live_jobs").unwrap().as_usize(), Some(r.peak_slab));
        let prof = scen[0].get("profile").expect("per-phase profile present");
        assert!(prof.get("dispatch_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(prof.get("sched_ns").is_some() && prof.get("dmr_ns").is_some());
        assert!(prof.get("sched_share").unwrap().as_f64().unwrap() >= 0.0);
        let totals = parsed.get("totals").unwrap();
        assert_eq!(totals.get("runs").unwrap().as_usize(), Some(2));
        assert!((totals.get("wall_secs").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-9);
    }
}
