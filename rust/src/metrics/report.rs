//! Report emitters: one function per paper table/figure.  Each renders an
//! ASCII artifact (printed by the benches / CLI) and returns CSV rows for
//! `results/`.

use super::summary::RunSummary;
use crate::des::ActionStats;
use crate::util::plot::{bar_chart, step_chart};
use crate::util::stats::Summary;
use crate::util::table::Table;

fn fmt(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Table 2: analysis of the actions performed by the framework
/// (sync vs async) in a 400-job workload.
pub fn table2(sync: &ActionStats, asy: &ActionStats, jobs: usize) -> Table {
    let mut t = Table::new(vec!["Action", "Measure", "Synchronous", "Asynchronous"])
        .with_title("Table 2: actions performed by the framework (400-job workload)");
    let sect = |t: &mut Table, name: &str, s: &Summary, a: &Summary| {
        t.row(vec![name.into(), "Minimum Time (s)".into(), fmt(s.min(), 4), fmt(a.min(), 4)]);
        t.row(vec![name.into(), "Maximum Time (s)".into(), fmt(s.max(), 4), fmt(a.max(), 4)]);
        t.row(vec![name.into(), "Average Time (s)".into(), fmt(s.mean(), 4), fmt(a.mean(), 4)]);
        t.row(vec![
            name.into(),
            "Standard Deviation (s)".into(),
            fmt(s.std(), 4),
            fmt(a.std(), 4),
        ]);
        t.row(vec![
            name.into(),
            "Quantity".into(),
            format!("{}", s.count()),
            format!("{}", a.count()),
        ]);
        t.row(vec![
            name.into(),
            "Actions/Job".into(),
            fmt(s.count() as f64 / jobs as f64, 3),
            fmt(a.count() as f64 / jobs as f64, 3),
        ]);
    };
    sect(&mut t, "No Action", &sync.no_action, &asy.no_action);
    sect(&mut t, "Expand", &sync.expand, &asy.expand);
    sect(&mut t, "Shrink", &sync.shrink, &asy.shrink);
    t
}

/// Table 3: cluster and job measures, fixed vs sync vs async.
pub fn table3(fixed: &RunSummary, sync: &RunSummary, asy: &RunSummary) -> Table {
    let mut t = Table::new(vec!["Measure", "", "Fixed", "Synchronous", "Asynchronous"])
        .with_title("Table 3: cluster and job measures of the 400-job workloads");
    t.row(vec![
        "Resources utilization".into(),
        "Avg. (%)".into(),
        fmt(fixed.util_mean * 100.0, 3),
        fmt(sync.util_mean * 100.0, 3),
        fmt(asy.util_mean * 100.0, 3),
    ]);
    t.row(vec![
        "Resources utilization".into(),
        "Std. (%)".into(),
        fmt(fixed.util_std * 100.0, 3),
        fmt(sync.util_std * 100.0, 3),
        fmt(asy.util_std * 100.0, 3),
    ]);
    let (ws, es, cs) = sync.gains_vs(fixed);
    let (wa, ea, ca) = asy.gains_vs(fixed);
    let mut gain = |name: &str, s: &Summary, a: &Summary| {
        t.row(vec![
            name.to_string(),
            "Avg. (%)".into(),
            "-".into(),
            fmt(s.mean(), 3),
            fmt(a.mean(), 3),
        ]);
        t.row(vec![
            name.to_string(),
            "Std. (%)".into(),
            "-".into(),
            fmt(s.std(), 3),
            fmt(a.std(), 3),
        ]);
    };
    gain("Waiting time gain", &ws, &wa);
    gain("Execution time gain", &es, &ea);
    gain("Completion time gain", &cs, &ca);
    t
}

/// Table 4: the summary measures for every workload size.
pub fn table4(rows: &[(usize, RunSummary, RunSummary)]) -> Table {
    let mut t = Table::new(vec![
        "#Jobs",
        "Version",
        "Utilization Rate",
        "Job Waiting Time",
        "Job Execution Time",
        "Job Completion Time",
    ])
    .with_title("Table 4: summary of the averaged measures from all the workloads");
    for (n, fixed, flex) in rows {
        for s in [fixed, flex] {
            t.row(vec![
                format!("{n}"),
                s.label.clone(),
                format!("{:.2} %", s.util_mean * 100.0),
                format!("{:.2} s", s.wait.mean()),
                format!("{:.2} s", s.exec.mean()),
                format!("{:.2} s", s.completion.mean()),
            ]);
        }
    }
    t
}

/// Fig. 4: workload completion times with flexible-gain labels.
pub fn fig4(rows: &[(usize, RunSummary, RunSummary)]) -> String {
    let mut entries = Vec::new();
    for (n, fixed, flex) in rows {
        entries.push((format!("{n} fixed"), fixed.makespan, String::new()));
        let gain = crate::util::stats::gain_pct(fixed.makespan, flex.makespan);
        entries.push((format!("{n} flex"), flex.makespan, format!("(gain {gain:.1}%)")));
    }
    bar_chart("Fig 4: workload execution times (s)", &entries, 50)
}

/// Fig. 5: average waiting times with gain labels.
pub fn fig5(rows: &[(usize, RunSummary, RunSummary)]) -> String {
    let mut entries = Vec::new();
    for (n, fixed, flex) in rows {
        entries.push((format!("{n} fixed"), fixed.wait.mean(), String::new()));
        let gain = crate::util::stats::gain_pct(fixed.wait.mean(), flex.wait.mean());
        entries.push((format!("{n} flex"), flex.wait.mean(), format!("(gain {gain:.1}%)")));
    }
    bar_chart("Fig 5: average job waiting time (s)", &entries, 50)
}

/// Fig. 6: time evolution of one workload (allocated nodes + running jobs
/// on top; completed jobs at the bottom), fixed vs flexible.
pub fn fig6(fixed: &RunSummary, flex: &RunSummary) -> String {
    let mut s = String::new();
    s.push_str(&step_chart(
        "Fig 6 (top): allocated nodes & running jobs",
        &[
            ("alloc-fixed".into(), fixed.alloc_series.clone()),
            ("alloc-flex".into(), flex.alloc_series.clone()),
            ("run-fixed".into(), fixed.running_series.clone()),
            ("run-flex".into(), flex.running_series.clone()),
        ],
        100,
        16,
    ));
    s.push_str(&step_chart(
        "Fig 6 (bottom): completed jobs",
        &[
            ("done-fixed".into(), fixed.completed_series.clone()),
            ("done-flex".into(), flex.completed_series.clone()),
        ],
        100,
        12,
    ));
    s
}

/// Fig. 7 + Fig. 8 data: per-job times (fixed vs flexible matched by
/// name) grouped by application.  Returns CSV rows:
/// app, name, wait_fixed, wait_flex, exec_fixed, exec_flex, d_wait,
/// d_exec, d_completion.
pub fn perjob_rows(fixed: &RunSummary, flex: &RunSummary) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for f in &fixed.jobs {
        if let Some(x) = flex.jobs.iter().find(|x| x.name == f.name) {
            rows.push(vec![
                f.app.name().to_string(),
                f.name.clone(),
                fmt(f.wait(), 1),
                fmt(x.wait(), 1),
                fmt(f.exec(), 1),
                fmt(x.exec(), 1),
                fmt(f.wait() - x.wait(), 1),
                fmt(f.exec() - x.exec(), 1),
                fmt(f.completion() - x.completion(), 1),
            ]);
        }
    }
    rows
}

/// Fig. 7/8 ASCII preview: per-app average exec/wait and deltas.
pub fn fig7_fig8_preview(fixed: &RunSummary, flex: &RunSummary) -> String {
    let mut t = Table::new(vec![
        "App",
        "exec fixed",
        "exec flex",
        "wait fixed",
        "wait flex",
        "Δcompletion (avg)",
    ])
    .with_title("Fig 7/8: per-job times grouped by application (averages)");
    for app in crate::apps::config::AppKind::WORKLOAD_APPS {
        let sel = |s: &RunSummary, f: fn(&super::record::JobRecord) -> f64| {
            Summary::from_iter(s.jobs.iter().filter(|j| j.app == app).map(f))
        };
        let fe = sel(fixed, |j| j.exec());
        let xe = sel(flex, |j| j.exec());
        let fw = sel(fixed, |j| j.wait());
        let xw = sel(flex, |j| j.wait());
        let fc = sel(fixed, |j| j.completion());
        let xc = sel(flex, |j| j.completion());
        t.row(vec![
            app.name().to_string(),
            fmt(fe.mean(), 0),
            fmt(xe.mean(), 0),
            fmt(fw.mean(), 0),
            fmt(xw.mean(), 0),
            fmt(fc.mean() - xc.mean(), 0),
        ]);
    }
    t.render()
}

/// CSV rows for the Table 4 / Fig 4 / Fig 5 sweep.
pub fn throughput_rows(rows: &[(usize, RunSummary, RunSummary)]) -> Vec<Vec<String>> {
    let mut out = Vec::new();
    for (n, fixed, flex) in rows {
        for s in [fixed, flex] {
            out.push(vec![
                n.to_string(),
                s.label.clone(),
                fmt(s.makespan, 1),
                fmt(s.util_mean * 100.0, 2),
                fmt(s.wait.mean(), 1),
                fmt(s.exec.mean(), 1),
                fmt(s.completion.mean(), 1),
                fmt(s.node_seconds(), 0),
            ]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Engine};
    use crate::metrics::RunSummary;
    use crate::workload;

    fn pair(n: usize, seed: u64) -> (usize, RunSummary, RunSummary) {
        let w = workload::generate(n, seed);
        let fixed =
            RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w.as_fixed(), "Fixed"));
        let flex =
            RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w, "Flexible"));
        (n, fixed, flex)
    }

    #[test]
    fn all_reports_render() {
        let p = pair(15, 2);
        let rows = vec![p];
        let t4 = table4(&rows).render();
        assert!(t4.contains("Fixed") && t4.contains("Flexible"));
        let f4 = fig4(&rows);
        assert!(f4.contains("gain"));
        let f5 = fig5(&rows);
        assert!(f5.contains("gain"));
        let (_, fixed, flex) = &rows[0];
        let f6 = fig6(fixed, flex);
        assert!(f6.contains("allocated nodes"));
        let pj = perjob_rows(fixed, flex);
        assert_eq!(pj.len(), 15);
        let prev = fig7_fig8_preview(fixed, flex);
        assert!(prev.contains("CG"));
        let t3 = table3(fixed, flex, flex).render();
        assert!(t3.contains("utilization"));
        let t2 = table2(&fixed.actions, &flex.actions, 15).render();
        assert!(t2.contains("Expand"));
        let tr = throughput_rows(&rows);
        assert_eq!(tr.len(), 2);
    }
}
