//! Aggregated per-run measures (the rows of Tables 3–4), for flat and
//! federated runs.

use super::record::{extract, JobRecord, MetricsFold};
use crate::des::{ActionStats, RunResult};
use crate::federation::{FedRunResult, RoutingPolicy, StealPolicy};
use crate::obs::PhaseProfile;
use crate::resilience::ResilienceStats;
use crate::rms::PassStats;
use crate::util::stats::Summary;

/// Everything the reports need from one workload run.
///
/// Every job-derived measure comes from the archive-time
/// [`MetricsFold`], never from the retained records — so a summary is
/// identical whether the run kept its per-job records
/// (`RmsConfig::keep_records`) or streamed them away.  The `jobs`
/// vector and the telemetry series are populated only under retention;
/// per-job reports and `gains_vs` need them, the CSV columns do not.
pub struct RunSummary {
    pub label: String,
    /// Retained per-job records (empty when the run streamed them away).
    pub jobs: Vec<JobRecord>,
    pub makespan: f64,
    /// Mean of the allocated-nodes fraction over the makespan ("resource
    /// utilization") — exact, from the fold's streaming integral.
    pub util_mean: f64,
    /// Time-weighted std of the busy fraction.  Series-derived: `0.0`
    /// when the telemetry was not retained (paper tables only — never a
    /// CSV column, so streamed and materialized CSVs still match).
    pub util_std: f64,
    pub wait: Summary,
    pub exec: Summary,
    pub completion: Summary,
    pub nodes: usize,
    /// Fig. 6 series: (t, allocated nodes), (t, running jobs),
    /// (t, completed jobs).
    pub alloc_series: Vec<(f64, f64)>,
    pub running_series: Vec<(f64, f64)>,
    pub completed_series: Vec<(f64, f64)>,
    pub actions: crate::des::ActionStats,
    /// Fault-injection measures (zeros / availability 1.0 without faults).
    pub resilience: crate::resilience::ResilienceStats,
    /// Per-job bounded slowdown ([`JobRecord::bounded_slowdown`]) — the
    /// policy-comparison headline: responsiveness normalized by job
    /// length.
    pub bounded_slowdown: Summary,
    /// Jain's fairness index over the per-user mean bounded slowdowns
    /// (1 = every user experiences the same slowdown; 1/users = one user
    /// bears it all).  `1.0` when the run has at most one user.
    pub fairness_jain: f64,
    /// Jobs that carried a soft deadline.
    pub deadline_jobs: usize,
    /// Deadline-carrying jobs that finished strictly late.
    pub deadline_misses: usize,
    /// Deterministic scheduling-pass / DMR-check counters (summed across
    /// shards for federated runs) — safe for the worker-count-invariant
    /// CSVs, unlike the wall-clock profile.
    pub passes: PassStats,
    /// Discrete events the engine processed (the events/s denominator).
    pub events: u64,
    /// Host-side wall-clock phase profile.  Timing noise: reported only
    /// through non-deterministic channels (campaign stdout table,
    /// `BENCH_*.json`) — never the CSVs.
    pub profile: PhaseProfile,
    /// Federated-run extras (`None` for flat runs): per-shard measures
    /// plus the meta-scheduler configuration that produced them.
    pub federation: Option<FedSummary>,
    /// Total node-seconds allocated to user jobs (archive-time fold).
    pub node_seconds_sum: f64,
    /// Peak-resident job count: the high-water mark of the manager's
    /// live map (summed across shards for federated runs).  The
    /// streaming memory model is bounded by this, not the total job
    /// count.
    pub peak_live: usize,
}

/// Federation-level measures of one federated run.
pub struct FedSummary {
    /// Shard count.
    pub shards: usize,
    /// Routing-policy label (`rr` | `ll` | `loc`).
    pub routing: String,
    /// Work-stealing-policy label (`off` | `head` | `half`).
    pub steal: String,
    /// Total jobs stolen across shards.
    pub steals: u64,
    /// Jain's fairness index over the per-shard mean bounded slowdowns
    /// (1 = every shard's jobs see the same slowdown) — the federation's
    /// load-balance headline.
    pub shard_jain: f64,
    /// Jobs evacuated off outage-struck shards (checkpointed state
    /// requeued on a surviving shard).
    pub evacuations: u64,
    /// Cross-shard requeues received: jobs that finished on a different
    /// shard than the one that first held them, due to an outage.
    pub cross_requeues: u64,
    /// One entry per shard, in shard-id order.
    pub per_shard: Vec<ShardSummary>,
}

/// Per-shard measures of one federated run.
pub struct ShardSummary {
    /// Shard id.
    pub shard: usize,
    /// Nodes in this shard's pool.
    pub nodes: usize,
    /// Relative node speed.
    pub speed: f64,
    /// Jobs this shard completed (includes stolen-in jobs).
    pub jobs: usize,
    /// Mean allocated-nodes percentage over the *federation* makespan.
    pub util_pct: f64,
    /// Time-averaged queue depth by Little's law: total job waiting time
    /// on this shard divided by the makespan.
    pub queue_depth: f64,
    /// Jobs stolen into this shard.
    pub steals_in: u64,
    /// Jobs stolen out of this shard.
    pub steals_out: u64,
    /// Jobs evacuated into this shard after another shard's outage.
    pub evac_in: u64,
    /// Jobs evacuated off this shard by its own outages.
    pub evac_out: u64,
    /// Arrivals the meta-scheduler routed here.
    pub routed: u64,
    /// This shard's availability (1.0 without faults).
    pub availability: f64,
    /// This shard's event-log digest (shard-layout determinism handle).
    pub log_digest: u64,
}

/// Sum `k` step series (as emitted by the telemetry) into one step
/// series: at every change point of any input, the output holds the sum
/// of the inputs' current values.
fn merge_step_series(series: &[&[(f64, f64)]]) -> Vec<(f64, f64)> {
    let mut idx = vec![0usize; series.len()];
    let mut cur = vec![0.0f64; series.len()];
    let mut out: Vec<(f64, f64)> = Vec::new();
    loop {
        let mut next_t = f64::INFINITY;
        for (s, &i) in series.iter().zip(idx.iter()) {
            if i < s.len() {
                next_t = next_t.min(s[i].0);
            }
        }
        if !next_t.is_finite() {
            break;
        }
        for ((s, i), c) in series.iter().zip(idx.iter_mut()).zip(cur.iter_mut()) {
            while *i < s.len() && s[*i].0 <= next_t {
                *c = s[*i].1;
                *i += 1;
            }
        }
        let total: f64 = cur.iter().sum();
        out.push((next_t, total));
    }
    out
}

/// Jain's fairness index over `values`: `(Σx)² / (n · Σx²)`.  Ranges from
/// `1/n` (maximally unfair) to `1` (perfectly even); empty or all-zero
/// input counts as perfectly fair.
pub fn jain_index(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 1.0;
    }
    let sum: f64 = values.iter().sum();
    let sq: f64 = values.iter().map(|v| v * v).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (values.len() as f64 * sq)
}

impl RunSummary {
    /// Summarize a flat run.  Takes the result by value so the telemetry
    /// series move into the summary instead of being cloned (they are the
    /// run's largest allocations; nothing downstream needs the raw
    /// `RunResult` once summarized).
    pub fn from_run(mut r: RunResult) -> RunSummary {
        // Defensive re-seal (idempotent): the engine seals at the end of
        // its event loop, but a summary must never read an open integral.
        r.rms.seal_metrics(r.makespan);
        let jobs = extract(&r.rms);
        let nodes = r.rms.cluster.total();
        let passes = r.rms.pass_stats();
        let fold = r.rms.fold.clone();
        let peak_live = r.rms.peak_live();
        Self::assemble(
            r.label,
            r.makespan,
            nodes,
            jobs,
            &fold,
            std::mem::take(&mut r.rms.telemetry.alloc_series),
            std::mem::take(&mut r.rms.telemetry.running_series),
            std::mem::take(&mut r.rms.telemetry.completed_series),
            r.actions,
            r.resilience,
            passes,
            r.events,
            r.profile,
            None,
            peak_live,
        )
    }

    /// Summarize a federated run: job records merged across shards (in
    /// shard-id order), cluster series summed, utilization over the total
    /// node pool — plus the per-shard breakdown in
    /// [`RunSummary::federation`].
    pub fn from_fed(r: &FedRunResult, routing: RoutingPolicy, steal: StealPolicy) -> RunSummary {
        let t1 = r.makespan.max(1e-9);
        let nodes: usize = r.shards.iter().map(|s| s.nodes).sum();
        let mut jobs: Vec<JobRecord> = Vec::new();
        let mut per_shard = Vec::with_capacity(r.shards.len());
        let mut fold = MetricsFold::default();
        let mut peak_live = 0usize;
        for sh in &r.shards {
            let shard_jobs = extract(&sh.rms);
            let sf = &sh.rms.fold;
            let util = sf.util_area / t1 / sh.nodes.max(1) as f64;
            per_shard.push(ShardSummary {
                shard: sh.shard,
                nodes: sh.nodes,
                speed: sh.speed,
                jobs: sf.count() as usize,
                util_pct: util * 100.0,
                queue_depth: sf.wait.sum() / t1,
                steals_in: sh.steals_in,
                steals_out: sh.steals_out,
                evac_in: sh.evac_in,
                evac_out: sh.evac_out,
                routed: sh.routed,
                availability: sh.stats.availability,
                log_digest: sh.rms.log.digest(),
            });
            // Per-shard folds merge in shard-id order (deterministic).
            fold.merge(sf);
            peak_live += sh.rms.peak_live();
            jobs.extend(shard_jobs);
        }
        let collect = |pick: fn(&crate::rms::Telemetry) -> &Vec<(f64, f64)>| {
            let views: Vec<&[(f64, f64)]> =
                r.shards.iter().map(|s| pick(&s.rms.telemetry).as_slice()).collect();
            merge_step_series(&views)
        };
        // Load-balance headline: Jain over the per-shard mean bounded
        // slowdowns (a routing policy that starves one shard shows up
        // here even when the merged distribution looks fine).
        let shard_slowdowns: Vec<f64> =
            r.shards.iter().map(|sh| sh.rms.fold.bounded_slowdown.mean()).collect();
        let federation = FedSummary {
            shards: r.shards.len(),
            routing: routing.label().to_string(),
            steal: steal.label().to_string(),
            steals: r.steals(),
            shard_jain: jain_index(&shard_slowdowns),
            evacuations: r.evacuations(),
            cross_requeues: r.cross_shard_requeues(),
            per_shard,
        };
        let mut passes = PassStats::default();
        for sh in &r.shards {
            let p = sh.rms.pass_stats();
            passes.sched_passes += p.sched_passes;
            passes.sched_elided += p.sched_elided;
            passes.dmr_checks += p.dmr_checks;
            passes.dmr_elided += p.dmr_elided;
        }
        Self::assemble(
            r.label.clone(),
            r.makespan,
            nodes,
            jobs,
            &fold,
            collect(|t| &t.alloc_series),
            collect(|t| &t.running_series),
            collect(|t| &t.completed_series),
            r.actions.clone(),
            r.resilience.clone(),
            passes,
            r.events,
            r.profile.clone(),
            Some(federation),
            peak_live,
        )
    }

    /// Shared constructor: derives every job measure from the
    /// archive-time fold (identical arithmetic for flat and federated
    /// runs, and for both memory models); the retained records and
    /// series ride along for the per-job reports when present.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        label: String,
        makespan: f64,
        nodes: usize,
        jobs: Vec<JobRecord>,
        fold: &MetricsFold,
        alloc_series: Vec<(f64, f64)>,
        running_series: Vec<(f64, f64)>,
        completed_series: Vec<(f64, f64)>,
        actions: ActionStats,
        resilience: ResilienceStats,
        passes: PassStats,
        events: u64,
        profile: PhaseProfile,
        federation: Option<FedSummary>,
        peak_live: usize,
    ) -> RunSummary {
        let t0 = 0.0;
        let t1 = makespan.max(1e-9);
        let util_mean = fold.util_area / t1 / nodes as f64;
        // Time-weighted std of the busy fraction — needs the retained
        // alloc series; 0.0 under the streaming memory model (the paper
        // tables that quote it require retention anyway).
        let util_std = if alloc_series.is_empty() {
            0.0
        } else {
            let mut acc = 0.0;
            let mut prev_t = t0;
            let mut prev_v = 0.0;
            for &(t, v) in &alloc_series {
                let tc = t.clamp(t0, t1);
                let f = prev_v / nodes as f64;
                acc += (f - util_mean) * (f - util_mean) * (tc - prev_t).max(0.0);
                prev_t = tc;
                prev_v = v;
            }
            let f = prev_v / nodes as f64;
            acc += (f - util_mean) * (f - util_mean) * (t1 - prev_t).max(0.0);
            (acc / (t1 - t0)).sqrt()
        };
        // Policy-comparison measures: bounded slowdown, per-user fairness
        // (Jain over per-user mean slowdowns), deadline misses — all from
        // the fold's streaming accumulators.
        let fairness_jain = jain_index(&fold.user_mean_slowdowns());
        RunSummary {
            label,
            makespan,
            util_mean,
            util_std,
            wait: fold.wait.clone(),
            exec: fold.exec.clone(),
            completion: fold.completion.clone(),
            nodes,
            alloc_series,
            running_series,
            completed_series,
            actions,
            resilience,
            bounded_slowdown: fold.bounded_slowdown.clone(),
            fairness_jain,
            deadline_jobs: fold.deadline_jobs,
            deadline_misses: fold.deadline_misses,
            passes,
            events,
            profile,
            federation,
            node_seconds_sum: fold.node_seconds,
            peak_live,
            jobs,
        }
    }

    /// Per-job percentage gains versus a baseline run (jobs matched by
    /// name — both runs process the same stream).  Returns
    /// (wait, exec, completion) gain summaries, positive = improvement.
    pub fn gains_vs(&self, base: &RunSummary) -> (Summary, Summary, Summary) {
        let mut wait = Summary::new();
        let mut exec = Summary::new();
        let mut comp = Summary::new();
        for j in &self.jobs {
            if let Some(b) = base.jobs.iter().find(|b| b.name == j.name) {
                // Jobs with ~zero baseline wait are skipped for the wait
                // gain (as in the paper, gains are relative).
                if b.wait() > 1.0 {
                    wait.push(crate::util::stats::gain_pct(b.wait(), j.wait()));
                }
                exec.push(crate::util::stats::gain_pct(b.exec(), j.exec()));
                comp.push(crate::util::stats::gain_pct(b.completion(), j.completion()));
            }
        }
        (wait, exec, comp)
    }

    /// Total node-seconds allocated to user jobs (from the archive-time
    /// fold, so it is exact even when per-job records are not retained).
    pub fn node_seconds(&self) -> f64 {
        self.node_seconds_sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Engine};
    use crate::workload;

    #[test]
    fn summary_from_small_run() {
        let w = workload::generate(10, 3);
        let r = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let events = r.events;
        let s = RunSummary::from_run(r);
        assert_eq!(s.jobs.len(), 10);
        assert_eq!(s.events, events);
        assert!(s.passes.sched_passes > 0, "pass counters ride along");
        assert!(s.util_mean > 0.0 && s.util_mean <= 1.0);
        assert!(s.makespan > 0.0);
        assert!(s.wait.count() == 10);
        assert!(s.node_seconds() > 0.0);
        // policy-comparison measures have sane ranges
        assert_eq!(s.bounded_slowdown.count(), 10);
        assert!(s.bounded_slowdown.min() >= 1.0);
        assert!(s.fairness_jain > 0.0 && s.fairness_jain <= 1.0 + 1e-12);
        assert_eq!(s.deadline_jobs, 0, "no deadlines by default");
        assert_eq!(s.deadline_misses, 0);
    }

    #[test]
    fn jain_index_ranges() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12, "even = 1");
        // one user bears everything: 1/n
        let j = jain_index(&[9.0, 0.0, 0.0]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12, "{j}");
        // mild imbalance sits in between
        let j = jain_index(&[1.0, 2.0]);
        assert!(j > 0.5 && j < 1.0);
    }

    #[test]
    fn deadline_misses_counted_under_tight_slack() {
        // Slack 1.01 on a contended cluster: queue waits guarantee misses.
        let w = workload::generate(20, 5).with_deadlines(1.01);
        let r = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let s = RunSummary::from_run(r);
        assert_eq!(s.deadline_jobs, 20);
        assert!(s.deadline_misses > 0, "tight deadlines must miss under contention");
        assert!(s.deadline_misses <= s.deadline_jobs);
    }

    #[test]
    fn federated_summary_merges_across_shards() {
        use crate::federation::{FedEngine, FederationConfig, RoutingPolicy, ShardSpec};
        let w = workload::generate(24, 9);
        let fed = FederationConfig {
            shards: ShardSpec::uniform(64, 2),
            routing: RoutingPolicy::RoundRobin,
            ..Default::default()
        };
        let r = FedEngine::new(DesConfig::default(), fed).run(&w, "fed");
        let events = r.events;
        let per_shard_passes: u64 =
            r.shards.iter().map(|sh| sh.rms.pass_stats().sched_passes).sum();
        let s = RunSummary::from_fed(&r, RoutingPolicy::RoundRobin, StealPolicy::Off);
        // Job records merge across shards; per-shard breakdown survives.
        assert_eq!(s.jobs.len(), 24);
        let f = s.federation.as_ref().expect("federated extras");
        assert_eq!(f.shards, 2);
        assert_eq!(f.per_shard.len(), 2);
        assert_eq!(f.steal, "off");
        assert!(f.shard_jain > 0.0 && f.shard_jain <= 1.0 + 1e-12, "{}", f.shard_jain);
        assert_eq!(f.evacuations, 0, "no outages, no evacuations");
        assert_eq!(f.cross_requeues, 0);
        assert!(f.per_shard.iter().all(|p| p.evac_in == 0 && p.evac_out == 0));
        assert_eq!(f.per_shard.iter().map(|p| p.jobs).sum::<usize>(), 24);
        // The merged alloc series never exceeds the total pool and the
        // summed step series covers both shards' allocations.
        assert_eq!(s.nodes, 64);
        assert!(s.alloc_series.iter().all(|&(_, v)| v <= 64.0));
        assert!(s.util_mean > 0.0 && s.util_mean <= 1.0);
        // Pass counters sum across shards; events ride along unchanged.
        assert_eq!(s.passes.sched_passes, per_shard_passes);
        assert!(s.passes.sched_passes > 0);
        assert_eq!(s.events, events);
    }

    #[test]
    fn gains_positive_when_flexible_faster() {
        let w = workload::generate(25, 11);
        let fixed = RunSummary::from_run(Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed"));
        let flex = RunSummary::from_run(Engine::new(DesConfig::default()).run(&w, "flexible"));
        let (wait, exec, comp) = flex.gains_vs(&fixed);
        // Waiting improves; execution degrades (negative gain); completion
        // improves on average — the paper's Table 3/4 signature.
        assert!(wait.mean() > 0.0, "wait gain {}", wait.mean());
        assert!(exec.mean() < 0.0, "exec gain {}", exec.mean());
        assert!(comp.mean() > 0.0, "completion gain {}", comp.mean());
    }
}
