//! Aggregated per-run measures (the rows of Tables 3–4).

use super::record::{extract, JobRecord};
use crate::des::RunResult;
use crate::util::stats::{step_series_mean, Summary};

/// Everything the reports need from one workload run.
pub struct RunSummary {
    pub label: String,
    pub jobs: Vec<JobRecord>,
    pub makespan: f64,
    /// Mean / std of the allocated-nodes fraction over the makespan
    /// ("resource utilization").
    pub util_mean: f64,
    pub util_std: f64,
    pub wait: Summary,
    pub exec: Summary,
    pub completion: Summary,
    pub nodes: usize,
    /// Fig. 6 series: (t, allocated nodes), (t, running jobs),
    /// (t, completed jobs).
    pub alloc_series: Vec<(f64, f64)>,
    pub running_series: Vec<(f64, f64)>,
    pub completed_series: Vec<(f64, f64)>,
    pub actions: crate::des::ActionStats,
    /// Fault-injection measures (zeros / availability 1.0 without faults).
    pub resilience: crate::resilience::ResilienceStats,
}

impl RunSummary {
    pub fn from_run(r: &RunResult) -> RunSummary {
        let jobs = extract(&r.rms);
        let nodes = r.rms.cluster.total();
        let t0 = 0.0;
        let t1 = r.makespan.max(1e-9);
        let series = &r.rms.telemetry.alloc_series;
        let util_mean = step_series_mean(series, t0, t1) / nodes as f64;
        // time-weighted std of the busy fraction
        let util_std = {
            let mut acc = 0.0;
            let mut prev_t = t0;
            let mut prev_v = 0.0;
            for &(t, v) in series {
                let tc = t.clamp(t0, t1);
                let f = prev_v / nodes as f64;
                acc += (f - util_mean) * (f - util_mean) * (tc - prev_t).max(0.0);
                prev_t = tc;
                prev_v = v;
            }
            let f = prev_v / nodes as f64;
            acc += (f - util_mean) * (f - util_mean) * (t1 - prev_t).max(0.0);
            (acc / (t1 - t0)).sqrt()
        };
        RunSummary {
            label: r.label.clone(),
            makespan: r.makespan,
            util_mean,
            util_std,
            wait: Summary::from_iter(jobs.iter().map(|j| j.wait())),
            exec: Summary::from_iter(jobs.iter().map(|j| j.exec())),
            completion: Summary::from_iter(jobs.iter().map(|j| j.completion())),
            nodes,
            alloc_series: series.clone(),
            running_series: r.rms.telemetry.running_series.clone(),
            completed_series: r.rms.telemetry.completed_series.clone(),
            actions: r.actions.clone(),
            resilience: r.resilience.clone(),
            jobs,
        }
    }

    /// Per-job percentage gains versus a baseline run (jobs matched by
    /// name — both runs process the same stream).  Returns
    /// (wait, exec, completion) gain summaries, positive = improvement.
    pub fn gains_vs(&self, base: &RunSummary) -> (Summary, Summary, Summary) {
        let mut wait = Summary::new();
        let mut exec = Summary::new();
        let mut comp = Summary::new();
        for j in &self.jobs {
            if let Some(b) = base.jobs.iter().find(|b| b.name == j.name) {
                // Jobs with ~zero baseline wait are skipped for the wait
                // gain (as in the paper, gains are relative).
                if b.wait() > 1.0 {
                    wait.push(crate::util::stats::gain_pct(b.wait(), j.wait()));
                }
                exec.push(crate::util::stats::gain_pct(b.exec(), j.exec()));
                comp.push(crate::util::stats::gain_pct(b.completion(), j.completion()));
            }
        }
        (wait, exec, comp)
    }

    /// Total node-seconds allocated to user jobs.
    pub fn node_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.node_seconds).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Engine};
    use crate::workload;

    #[test]
    fn summary_from_small_run() {
        let w = workload::generate(10, 3);
        let r = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let s = RunSummary::from_run(&r);
        assert_eq!(s.jobs.len(), 10);
        assert!(s.util_mean > 0.0 && s.util_mean <= 1.0);
        assert!(s.makespan > 0.0);
        assert!(s.wait.count() == 10);
        assert!(s.node_seconds() > 0.0);
    }

    #[test]
    fn gains_positive_when_flexible_faster() {
        let w = workload::generate(25, 11);
        let fixed = RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed"));
        let flex = RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w, "flexible"));
        let (wait, exec, comp) = flex.gains_vs(&fixed);
        // Waiting improves; execution degrades (negative gain); completion
        // improves on average — the paper's Table 3/4 signature.
        assert!(wait.mean() > 0.0, "wait gain {}", wait.mean());
        assert!(exec.mean() < 0.0, "exec gain {}", exec.mean());
        assert!(comp.mean() > 0.0, "completion gain {}", comp.mean());
    }
}
