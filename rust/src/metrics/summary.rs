//! Aggregated per-run measures (the rows of Tables 3–4).

use super::record::{extract, JobRecord};
use crate::des::RunResult;
use crate::util::stats::{step_series_mean, Summary};

/// Everything the reports need from one workload run.
pub struct RunSummary {
    pub label: String,
    pub jobs: Vec<JobRecord>,
    pub makespan: f64,
    /// Mean / std of the allocated-nodes fraction over the makespan
    /// ("resource utilization").
    pub util_mean: f64,
    pub util_std: f64,
    pub wait: Summary,
    pub exec: Summary,
    pub completion: Summary,
    pub nodes: usize,
    /// Fig. 6 series: (t, allocated nodes), (t, running jobs),
    /// (t, completed jobs).
    pub alloc_series: Vec<(f64, f64)>,
    pub running_series: Vec<(f64, f64)>,
    pub completed_series: Vec<(f64, f64)>,
    pub actions: crate::des::ActionStats,
    /// Fault-injection measures (zeros / availability 1.0 without faults).
    pub resilience: crate::resilience::ResilienceStats,
    /// Per-job bounded slowdown ([`JobRecord::bounded_slowdown`]) — the
    /// policy-comparison headline: responsiveness normalized by job
    /// length.
    pub bounded_slowdown: Summary,
    /// Jain's fairness index over the per-user mean bounded slowdowns
    /// (1 = every user experiences the same slowdown; 1/users = one user
    /// bears it all).  `1.0` when the run has at most one user.
    pub fairness_jain: f64,
    /// Jobs that carried a soft deadline.
    pub deadline_jobs: usize,
    /// Deadline-carrying jobs that finished strictly late.
    pub deadline_misses: usize,
}

/// Jain's fairness index over `values`: `(Σx)² / (n · Σx²)`.  Ranges from
/// `1/n` (maximally unfair) to `1` (perfectly even); empty or all-zero
/// input counts as perfectly fair.
pub fn jain_index(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 1.0;
    }
    let sum: f64 = values.iter().sum();
    let sq: f64 = values.iter().map(|v| v * v).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (values.len() as f64 * sq)
}

impl RunSummary {
    pub fn from_run(r: &RunResult) -> RunSummary {
        let jobs = extract(&r.rms);
        let nodes = r.rms.cluster.total();
        let t0 = 0.0;
        let t1 = r.makespan.max(1e-9);
        let series = &r.rms.telemetry.alloc_series;
        let util_mean = step_series_mean(series, t0, t1) / nodes as f64;
        // time-weighted std of the busy fraction
        let util_std = {
            let mut acc = 0.0;
            let mut prev_t = t0;
            let mut prev_v = 0.0;
            for &(t, v) in series {
                let tc = t.clamp(t0, t1);
                let f = prev_v / nodes as f64;
                acc += (f - util_mean) * (f - util_mean) * (tc - prev_t).max(0.0);
                prev_t = tc;
                prev_v = v;
            }
            let f = prev_v / nodes as f64;
            acc += (f - util_mean) * (f - util_mean) * (t1 - prev_t).max(0.0);
            (acc / (t1 - t0)).sqrt()
        };
        // Policy-comparison measures: bounded slowdown, per-user fairness
        // (Jain over per-user mean slowdowns), deadline misses.
        let bounded_slowdown = Summary::from_iter(jobs.iter().map(|j| j.bounded_slowdown()));
        let mut per_user: std::collections::BTreeMap<u32, (f64, usize)> =
            std::collections::BTreeMap::new();
        for j in &jobs {
            let e = per_user.entry(j.user).or_insert((0.0, 0));
            e.0 += j.bounded_slowdown();
            e.1 += 1;
        }
        let user_means: Vec<f64> =
            per_user.values().map(|(sum, n)| sum / *n as f64).collect();
        let fairness_jain = jain_index(&user_means);
        let deadline_jobs = jobs.iter().filter(|j| j.deadline.is_some()).count();
        let deadline_misses = jobs.iter().filter(|j| j.missed_deadline()).count();
        RunSummary {
            label: r.label.clone(),
            makespan: r.makespan,
            util_mean,
            util_std,
            wait: Summary::from_iter(jobs.iter().map(|j| j.wait())),
            exec: Summary::from_iter(jobs.iter().map(|j| j.exec())),
            completion: Summary::from_iter(jobs.iter().map(|j| j.completion())),
            nodes,
            alloc_series: series.clone(),
            running_series: r.rms.telemetry.running_series.clone(),
            completed_series: r.rms.telemetry.completed_series.clone(),
            actions: r.actions.clone(),
            resilience: r.resilience.clone(),
            bounded_slowdown,
            fairness_jain,
            deadline_jobs,
            deadline_misses,
            jobs,
        }
    }

    /// Per-job percentage gains versus a baseline run (jobs matched by
    /// name — both runs process the same stream).  Returns
    /// (wait, exec, completion) gain summaries, positive = improvement.
    pub fn gains_vs(&self, base: &RunSummary) -> (Summary, Summary, Summary) {
        let mut wait = Summary::new();
        let mut exec = Summary::new();
        let mut comp = Summary::new();
        for j in &self.jobs {
            if let Some(b) = base.jobs.iter().find(|b| b.name == j.name) {
                // Jobs with ~zero baseline wait are skipped for the wait
                // gain (as in the paper, gains are relative).
                if b.wait() > 1.0 {
                    wait.push(crate::util::stats::gain_pct(b.wait(), j.wait()));
                }
                exec.push(crate::util::stats::gain_pct(b.exec(), j.exec()));
                comp.push(crate::util::stats::gain_pct(b.completion(), j.completion()));
            }
        }
        (wait, exec, comp)
    }

    /// Total node-seconds allocated to user jobs.
    pub fn node_seconds(&self) -> f64 {
        self.jobs.iter().map(|j| j.node_seconds).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Engine};
    use crate::workload;

    #[test]
    fn summary_from_small_run() {
        let w = workload::generate(10, 3);
        let r = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let s = RunSummary::from_run(&r);
        assert_eq!(s.jobs.len(), 10);
        assert!(s.util_mean > 0.0 && s.util_mean <= 1.0);
        assert!(s.makespan > 0.0);
        assert!(s.wait.count() == 10);
        assert!(s.node_seconds() > 0.0);
        // policy-comparison measures have sane ranges
        assert_eq!(s.bounded_slowdown.count(), 10);
        assert!(s.bounded_slowdown.min() >= 1.0);
        assert!(s.fairness_jain > 0.0 && s.fairness_jain <= 1.0 + 1e-12);
        assert_eq!(s.deadline_jobs, 0, "no deadlines by default");
        assert_eq!(s.deadline_misses, 0);
    }

    #[test]
    fn jain_index_ranges() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12, "even = 1");
        // one user bears everything: 1/n
        let j = jain_index(&[9.0, 0.0, 0.0]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12, "{j}");
        // mild imbalance sits in between
        let j = jain_index(&[1.0, 2.0]);
        assert!(j > 0.5 && j < 1.0);
    }

    #[test]
    fn deadline_misses_counted_under_tight_slack() {
        // Slack 1.01 on a contended cluster: queue waits guarantee misses.
        let w = workload::generate(20, 5).with_deadlines(1.01);
        let r = Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed");
        let s = RunSummary::from_run(&r);
        assert_eq!(s.deadline_jobs, 20);
        assert!(s.deadline_misses > 0, "tight deadlines must miss under contention");
        assert!(s.deadline_misses <= s.deadline_jobs);
    }

    #[test]
    fn gains_positive_when_flexible_faster() {
        let w = workload::generate(25, 11);
        let fixed = RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w.as_fixed(), "fixed"));
        let flex = RunSummary::from_run(&Engine::new(DesConfig::default()).run(&w, "flexible"));
        let (wait, exec, comp) = flex.gains_vs(&fixed);
        // Waiting improves; execution degrades (negative gain); completion
        // improves on average — the paper's Table 3/4 signature.
        assert!(wait.mean() > 0.0, "wait gain {}", wait.mean());
        assert!(exec.mean() < 0.0, "exec gain {}", exec.mean());
        assert!(comp.mean() > 0.0, "completion gain {}", comp.mean());
    }
}
