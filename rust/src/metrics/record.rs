//! Per-job measurement records extracted from a finished run, plus the
//! streaming [`MetricsFold`] accumulator that replaces record retention
//! on bounded-memory runs.

use std::collections::BTreeMap;

use crate::apps::config::AppKind;
use crate::rms::{Job, Rms};
use crate::util::stats::Summary;
use crate::Time;

/// The §7.5 per-job measures: waiting, execution and completion times.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Job name (unique within a workload).
    pub name: String,
    /// Application the job instantiated.
    pub app: AppKind,
    /// Submission time.
    pub submit: Time,
    /// Execution start time.
    pub start: Time,
    /// Finalization time.
    pub end: Time,
    /// Process count the job was submitted with.
    pub initial_procs: usize,
    /// Committed expansions over the job's lifetime.
    pub n_expands: usize,
    /// Committed shrinks over the job's lifetime.
    pub n_shrinks: usize,
    /// Node-seconds the job held (integral of its allocation over time).
    pub node_seconds: f64,
    /// Owning user (per-user fairness accounting).
    pub user: u32,
    /// Soft deadline, if the job carried one.
    pub deadline: Option<Time>,
}

impl JobRecord {
    /// Waiting time: submission until execution start.
    pub fn wait(&self) -> f64 {
        self.start - self.submit
    }
    /// Execution time: start until end.
    pub fn exec(&self) -> f64 {
        self.end - self.start
    }
    /// Completion (turnaround) time: submission until finalization.
    pub fn completion(&self) -> f64 {
        self.end - self.submit
    }
    /// Bounded slowdown: completion over execution, with the standard
    /// 10-second floor on the denominator so trivially-short jobs cannot
    /// dominate the metric, and clamped to ≥ 1.
    ///
    /// For a job that was killed and requeued by a node failure,
    /// `completion` spans the whole history while `exec` covers only the
    /// final incarnation (`start` is the last start), so lost work reads
    /// as slowdown.  Intentional — the user genuinely waited through the
    /// rework — but it means fault-sweep scenarios charge requeue-heavy
    /// strategies here *in addition to* the `rework_s` column; compare
    /// both columns, not just one, when recoveries differ.
    pub fn bounded_slowdown(&self) -> f64 {
        (self.completion() / self.exec().max(SLOWDOWN_BOUND)).max(1.0)
    }
    /// Whether the job finished after its soft deadline (jobs without a
    /// deadline never miss).
    pub fn missed_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| self.end > d + 1e-9)
    }
}

/// Denominator floor (seconds) of [`JobRecord::bounded_slowdown`] — the
/// conventional 10 s threshold from the scheduling literature.
pub const SLOWDOWN_BOUND: f64 = 10.0;

/// Extract user-job records (resizers excluded), sorted by submission.
pub fn extract(rms: &Rms) -> Vec<JobRecord> {
    let mut out: Vec<JobRecord> = rms
        .jobs()
        .filter(|j| !j.is_resizer && j.start_time.is_some() && j.end_time.is_some())
        .map(|j| {
            let start = j.start_time.unwrap();
            let end = j.end_time.unwrap();
            // Integrate the allocation over the resize history.
            let mut t = start;
            let mut procs = j.spec.procs as f64;
            let mut node_seconds = 0.0;
            for r in &j.resize_log {
                node_seconds += procs * (r.time - t);
                t = r.time;
                procs = r.to_procs as f64;
            }
            node_seconds += procs * (end - t);
            JobRecord {
                name: j.spec.name.clone(),
                app: j.spec.app,
                submit: j.submit_time,
                start,
                end,
                initial_procs: j.spec.procs,
                n_expands: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs > r.from_procs)
                    .count(),
                n_shrinks: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs < r.from_procs)
                    .count(),
                node_seconds,
                user: j.spec.user,
                deadline: j.spec.deadline,
            }
        })
        .collect();
    out.sort_by(|a, b| a.submit.total_cmp(&b.submit).then(a.name.cmp(&b.name)));
    out
}

/// Streaming accumulator of every run-level measure the reports derive
/// from per-job records.  The `Rms` folds each job into this at archive
/// time (`finish`/`cancel`), so a run's summary no longer requires the
/// records themselves to be retained — the canonical metrics path for
/// both streamed and materialized runs, which is what makes the two
/// bit-identical by construction.
///
/// The arithmetic mirrors [`extract`] + `RunSummary::assemble` exactly:
/// same job filter (resizers and never-started jobs excluded), same
/// resize-log walk for node-seconds, same bounded-slowdown formula.
/// Jobs fold in archive (finish-time) order, which is itself identical
/// across streamed and materialized runs of the same event stream.
#[derive(Debug, Clone, Default)]
pub struct MetricsFold {
    /// Waiting times (submission → start).
    pub wait: Summary,
    /// Execution times (start → end).
    pub exec: Summary,
    /// Completion (turnaround) times (submission → end).
    pub completion: Summary,
    /// Bounded slowdowns ([`JobRecord::bounded_slowdown`] formula).
    pub bounded_slowdown: Summary,
    /// Per-user (bounded-slowdown sum, job count) — the Jain fairness
    /// inputs, keyed in user-id order so the derived means are
    /// deterministic.
    pub per_user: BTreeMap<u32, (f64, u64)>,
    /// Jobs that carried a soft deadline.
    pub deadline_jobs: usize,
    /// Deadline-carrying jobs that finished strictly late.
    pub deadline_misses: usize,
    /// Total node-seconds allocated to user jobs (resize-log integral).
    pub node_seconds: f64,
    /// Timestamp of the last allocation observation (utilization
    /// integral state; fed by `Rms::snapshot` on every allocation
    /// change, *before* any telemetry stride gating).
    pub util_last_t: f64,
    /// Allocated-node count at the last observation.
    pub util_last_alloc: f64,
    /// Integral of allocated nodes over time — `∫ alloc(t) dt` from 0 to
    /// the last observation (seal at the makespan before reading).
    pub util_area: f64,
}

impl MetricsFold {
    /// Fold one archived job.  Applies the [`extract`] filter, so calling
    /// this on resizers or never-started (cancelled) jobs is a no-op.
    pub fn fold_job(&mut self, j: &Job) {
        if j.is_resizer {
            return;
        }
        let (Some(start), Some(end)) = (j.start_time, j.end_time) else {
            return;
        };
        let completion = end - j.submit_time;
        let exec = end - start;
        self.wait.push(start - j.submit_time);
        self.exec.push(exec);
        self.completion.push(completion);
        let slow = (completion / exec.max(SLOWDOWN_BOUND)).max(1.0);
        self.bounded_slowdown.push(slow);
        let e = self.per_user.entry(j.spec.user).or_insert((0.0, 0));
        e.0 += slow;
        e.1 += 1;
        if let Some(d) = j.spec.deadline {
            self.deadline_jobs += 1;
            if end > d + 1e-9 {
                self.deadline_misses += 1;
            }
        }
        // Allocation integral over the resize history — the same walk as
        // [`extract`], accumulated directly.
        let mut t = start;
        let mut procs = j.spec.procs as f64;
        for r in &j.resize_log {
            self.node_seconds += procs * (r.time - t);
            t = r.time;
            procs = r.to_procs as f64;
        }
        self.node_seconds += procs * (end - t);
    }

    /// Observe the allocated-node count at time `now`.  Step-function
    /// semantics identical to `step_series_mean` over the telemetry
    /// series: the previous value holds over `[last_t, now)`; repeated
    /// observations at one timestamp keep the latest value.
    pub fn observe_alloc(&mut self, now: f64, alloc: f64) {
        if now > self.util_last_t {
            self.util_area += self.util_last_alloc * (now - self.util_last_t);
            self.util_last_t = now;
        }
        self.util_last_alloc = alloc;
    }

    /// Close the utilization integral at the end of the run (`t1` = the
    /// makespan).  Idempotent; later [`MetricsFold::observe_alloc`] calls
    /// at earlier times become no-ops.
    pub fn seal_util(&mut self, t1: f64) {
        if t1 > self.util_last_t {
            self.util_area += self.util_last_alloc * (t1 - self.util_last_t);
            self.util_last_t = t1;
        }
    }

    /// Merge another fold into this one (federated runs merge per-shard
    /// folds in shard-id order).  The utilization *state* fields do not
    /// merge — seal both folds first; only the areas add.
    pub fn merge(&mut self, o: &MetricsFold) {
        self.wait.merge(&o.wait);
        self.exec.merge(&o.exec);
        self.completion.merge(&o.completion);
        self.bounded_slowdown.merge(&o.bounded_slowdown);
        for (u, (sum, n)) in &o.per_user {
            let e = self.per_user.entry(*u).or_insert((0.0, 0));
            e.0 += sum;
            e.1 += n;
        }
        self.deadline_jobs += o.deadline_jobs;
        self.deadline_misses += o.deadline_misses;
        self.node_seconds += o.node_seconds;
        self.util_area += o.util_area;
    }

    /// Jobs folded so far.
    pub fn count(&self) -> u64 {
        self.wait.count()
    }

    /// Per-user mean bounded slowdowns, in user-id order (the
    /// `jain_index` input).
    pub fn user_mean_slowdowns(&self) -> Vec<f64> {
        self.per_user.values().map(|(sum, n)| sum / *n as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::{DmrRequest, RmsConfig};
    use crate::workload::JobSpec;

    #[test]
    fn extract_computes_node_seconds_across_resizes() {
        let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
        let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        let a = rms.submit(spec, 0.0);
        rms.schedule(0.0); // 32 nodes
        // queue a job so the policy shrinks
        let waiting = JobSpec::from_app(AppKind::Cg, "CG-1".into(), 1.0, 1.0);
        rms.submit(waiting, 1.0);
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 10.0);
        assert!(matches!(out, crate::rms::DmrOutcome::Shrink { .. }));
        rms.commit_shrink_to(a, 8, 10.0);
        rms.finish(a, 20.0);

        let recs = extract(&rms);
        let r = recs.iter().find(|r| r.name == "CG-0").unwrap();
        assert_eq!(r.n_shrinks, 1);
        // 32 procs for 10 s + 8 procs for 10 s
        assert!((r.node_seconds - (320.0 + 80.0)).abs() < 1e-9);
        assert_eq!(r.wait(), 0.0);
        assert_eq!(r.exec(), 20.0);
    }

    #[test]
    fn bounded_slowdown_and_deadline_edges() {
        let mk = |submit: f64, start: f64, end: f64, deadline: Option<f64>| JobRecord {
            name: "j".into(),
            app: AppKind::Cg,
            submit,
            start,
            end,
            initial_procs: 4,
            n_expands: 0,
            n_shrinks: 0,
            node_seconds: 0.0,
            user: 0,
            deadline,
        };
        // 100 s exec, 100 s wait: slowdown 2.
        assert!((mk(0.0, 100.0, 200.0, None).bounded_slowdown() - 2.0).abs() < 1e-9);
        // Tiny job: denominator floors at 10 s instead of 1 s exec.
        assert!((mk(0.0, 9.0, 10.0, None).bounded_slowdown() - 1.0).abs() < 1e-9);
        // No wait: clamped to exactly 1.
        assert_eq!(mk(0.0, 0.0, 5.0, None).bounded_slowdown(), 1.0);
        // Deadline edges: exactly on time is not a miss, strictly late is.
        assert!(!mk(0.0, 0.0, 50.0, Some(50.0)).missed_deadline());
        assert!(mk(0.0, 0.0, 50.1, Some(50.0)).missed_deadline());
        assert!(!mk(0.0, 0.0, 50.0, None).missed_deadline());
    }

    #[test]
    fn fold_matches_extract_on_a_run() {
        // Drive a small run through the engine; the archive-time fold
        // must agree with the batch extract()-based formulas.
        use crate::des::{DesConfig, Engine};
        let w = crate::workload::generate(30, 11).with_deadlines(1.5);
        let r = Engine::new(DesConfig::default()).run(&w, "fold");
        let recs = extract(&r.rms);
        let fold = &r.rms.fold;
        assert_eq!(fold.count(), recs.len() as u64);
        let near = |a: f64, b: f64| (a - b).abs() < 1e-6 * b.abs().max(1.0);
        assert!(near(fold.wait.sum(), recs.iter().map(|j| j.wait()).sum()));
        assert!(near(fold.exec.sum(), recs.iter().map(|j| j.exec()).sum()));
        assert!(near(
            fold.bounded_slowdown.sum(),
            recs.iter().map(|j| j.bounded_slowdown()).sum()
        ));
        assert!(near(fold.node_seconds, recs.iter().map(|j| j.node_seconds).sum()));
        assert_eq!(fold.deadline_jobs, recs.iter().filter(|j| j.deadline.is_some()).count());
        assert_eq!(fold.deadline_misses, recs.iter().filter(|j| j.missed_deadline()).count());
        // min/max are order-independent, so they match exactly.
        let wmin = recs.iter().map(|j| j.wait()).fold(f64::INFINITY, f64::min);
        assert_eq!(fold.wait.min(), wmin);
    }

    #[test]
    fn fold_skips_resizers_and_unstarted_jobs() {
        let mut fold = MetricsFold::default();
        let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        let mut j = Job::new(1, spec, 0.0);
        fold.fold_job(&j); // never started
        assert_eq!(fold.count(), 0);
        j.start_time = Some(1.0);
        fold.fold_job(&j); // started, never ended
        assert_eq!(fold.count(), 0);
        j.end_time = Some(5.0);
        j.is_resizer = true;
        fold.fold_job(&j);
        assert_eq!(fold.count(), 0, "resizers are not user jobs");
        j.is_resizer = false;
        fold.fold_job(&j);
        assert_eq!(fold.count(), 1);
        assert!((fold.wait.mean() - 1.0).abs() < 1e-12);
        assert!((fold.exec.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn util_integral_matches_step_series_mean() {
        use crate::util::stats::step_series_mean;
        let pts = [(0.0, 2.0), (5.0, 4.0), (5.0, 6.0), (8.0, 0.0), (9.0, 3.0)];
        let mut fold = MetricsFold::default();
        for &(t, v) in &pts {
            fold.observe_alloc(t, v);
        }
        fold.seal_util(12.0);
        let want = step_series_mean(&pts, 0.0, 12.0);
        assert!((fold.util_area / 12.0 - want).abs() < 1e-12);
        // sealing twice is a no-op
        let area = fold.util_area;
        fold.seal_util(12.0);
        fold.seal_util(10.0);
        assert_eq!(fold.util_area, area);
    }

    #[test]
    fn fold_merge_matches_single_fold() {
        // Split one observation stream across two folds; merging must
        // reproduce the whole (Welford-merge + scalar sums).
        let mk = |lo: usize, hi: usize| {
            let mut f = MetricsFold::default();
            for i in lo..hi {
                let spec = JobSpec::from_app(AppKind::Cg, format!("j{i}"), i as f64, 1.0);
                let mut j = Job::new(i as u64, spec, i as f64);
                j.spec.user = (i % 3) as u32;
                j.spec.deadline = Some(i as f64 + 100.0);
                j.start_time = Some(i as f64 + 1.0 + i as f64 * 0.1);
                j.end_time = Some(i as f64 + 50.0 + (i % 7) as f64 * 90.0);
                f.fold_job(&j);
            }
            f
        };
        let whole = mk(0, 20);
        let mut merged = mk(0, 8);
        merged.merge(&mk(8, 20));
        assert_eq!(merged.count(), whole.count());
        assert!((merged.wait.mean() - whole.wait.mean()).abs() < 1e-9);
        assert!((merged.completion.std() - whole.completion.std()).abs() < 1e-9);
        assert_eq!(merged.deadline_jobs, whole.deadline_jobs);
        assert_eq!(merged.deadline_misses, whole.deadline_misses);
        assert!((merged.node_seconds - whole.node_seconds).abs() < 1e-9);
        assert_eq!(merged.user_mean_slowdowns().len(), whole.user_mean_slowdowns().len());
        for (a, b) in merged.user_mean_slowdowns().iter().zip(whole.user_mean_slowdowns()) {
            assert!((a - b).abs() < 1e-9);
        }
    }
}
