//! Per-job measurement records extracted from a finished run.

use crate::apps::config::AppKind;
use crate::rms::Rms;
use crate::Time;

/// The §7.5 per-job measures: waiting, execution and completion times.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Job name (unique within a workload).
    pub name: String,
    /// Application the job instantiated.
    pub app: AppKind,
    /// Submission time.
    pub submit: Time,
    /// Execution start time.
    pub start: Time,
    /// Finalization time.
    pub end: Time,
    /// Process count the job was submitted with.
    pub initial_procs: usize,
    /// Committed expansions over the job's lifetime.
    pub n_expands: usize,
    /// Committed shrinks over the job's lifetime.
    pub n_shrinks: usize,
    /// Node-seconds the job held (integral of its allocation over time).
    pub node_seconds: f64,
    /// Owning user (per-user fairness accounting).
    pub user: u32,
    /// Soft deadline, if the job carried one.
    pub deadline: Option<Time>,
}

impl JobRecord {
    /// Waiting time: submission until execution start.
    pub fn wait(&self) -> f64 {
        self.start - self.submit
    }
    /// Execution time: start until end.
    pub fn exec(&self) -> f64 {
        self.end - self.start
    }
    /// Completion (turnaround) time: submission until finalization.
    pub fn completion(&self) -> f64 {
        self.end - self.submit
    }
    /// Bounded slowdown: completion over execution, with the standard
    /// 10-second floor on the denominator so trivially-short jobs cannot
    /// dominate the metric, and clamped to ≥ 1.
    ///
    /// For a job that was killed and requeued by a node failure,
    /// `completion` spans the whole history while `exec` covers only the
    /// final incarnation (`start` is the last start), so lost work reads
    /// as slowdown.  Intentional — the user genuinely waited through the
    /// rework — but it means fault-sweep scenarios charge requeue-heavy
    /// strategies here *in addition to* the `rework_s` column; compare
    /// both columns, not just one, when recoveries differ.
    pub fn bounded_slowdown(&self) -> f64 {
        (self.completion() / self.exec().max(SLOWDOWN_BOUND)).max(1.0)
    }
    /// Whether the job finished after its soft deadline (jobs without a
    /// deadline never miss).
    pub fn missed_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| self.end > d + 1e-9)
    }
}

/// Denominator floor (seconds) of [`JobRecord::bounded_slowdown`] — the
/// conventional 10 s threshold from the scheduling literature.
pub const SLOWDOWN_BOUND: f64 = 10.0;

/// Extract user-job records (resizers excluded), sorted by submission.
pub fn extract(rms: &Rms) -> Vec<JobRecord> {
    let mut out: Vec<JobRecord> = rms
        .jobs()
        .filter(|j| !j.is_resizer && j.start_time.is_some() && j.end_time.is_some())
        .map(|j| {
            let start = j.start_time.unwrap();
            let end = j.end_time.unwrap();
            // Integrate the allocation over the resize history.
            let mut t = start;
            let mut procs = j.spec.procs as f64;
            let mut node_seconds = 0.0;
            for r in &j.resize_log {
                node_seconds += procs * (r.time - t);
                t = r.time;
                procs = r.to_procs as f64;
            }
            node_seconds += procs * (end - t);
            JobRecord {
                name: j.spec.name.clone(),
                app: j.spec.app,
                submit: j.submit_time,
                start,
                end,
                initial_procs: j.spec.procs,
                n_expands: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs > r.from_procs)
                    .count(),
                n_shrinks: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs < r.from_procs)
                    .count(),
                node_seconds,
                user: j.spec.user,
                deadline: j.spec.deadline,
            }
        })
        .collect();
    out.sort_by(|a, b| a.submit.total_cmp(&b.submit).then(a.name.cmp(&b.name)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::{DmrRequest, RmsConfig};
    use crate::workload::JobSpec;

    #[test]
    fn extract_computes_node_seconds_across_resizes() {
        let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
        let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        let a = rms.submit(spec, 0.0);
        rms.schedule(0.0); // 32 nodes
        // queue a job so the policy shrinks
        let waiting = JobSpec::from_app(AppKind::Cg, "CG-1".into(), 1.0, 1.0);
        rms.submit(waiting, 1.0);
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 10.0);
        assert!(matches!(out, crate::rms::DmrOutcome::Shrink { .. }));
        rms.commit_shrink_to(a, 8, 10.0);
        rms.finish(a, 20.0);

        let recs = extract(&rms);
        let r = recs.iter().find(|r| r.name == "CG-0").unwrap();
        assert_eq!(r.n_shrinks, 1);
        // 32 procs for 10 s + 8 procs for 10 s
        assert!((r.node_seconds - (320.0 + 80.0)).abs() < 1e-9);
        assert_eq!(r.wait(), 0.0);
        assert_eq!(r.exec(), 20.0);
    }

    #[test]
    fn bounded_slowdown_and_deadline_edges() {
        let mk = |submit: f64, start: f64, end: f64, deadline: Option<f64>| JobRecord {
            name: "j".into(),
            app: AppKind::Cg,
            submit,
            start,
            end,
            initial_procs: 4,
            n_expands: 0,
            n_shrinks: 0,
            node_seconds: 0.0,
            user: 0,
            deadline,
        };
        // 100 s exec, 100 s wait: slowdown 2.
        assert!((mk(0.0, 100.0, 200.0, None).bounded_slowdown() - 2.0).abs() < 1e-9);
        // Tiny job: denominator floors at 10 s instead of 1 s exec.
        assert!((mk(0.0, 9.0, 10.0, None).bounded_slowdown() - 1.0).abs() < 1e-9);
        // No wait: clamped to exactly 1.
        assert_eq!(mk(0.0, 0.0, 5.0, None).bounded_slowdown(), 1.0);
        // Deadline edges: exactly on time is not a miss, strictly late is.
        assert!(!mk(0.0, 0.0, 50.0, Some(50.0)).missed_deadline());
        assert!(mk(0.0, 0.0, 50.1, Some(50.0)).missed_deadline());
        assert!(!mk(0.0, 0.0, 50.0, None).missed_deadline());
    }
}
