//! Per-job measurement records extracted from a finished run.

use crate::apps::config::AppKind;
use crate::rms::Rms;
use crate::Time;

/// The §7.5 per-job measures: waiting, execution and completion times.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub name: String,
    pub app: AppKind,
    pub submit: Time,
    pub start: Time,
    pub end: Time,
    pub initial_procs: usize,
    pub n_expands: usize,
    pub n_shrinks: usize,
    /// Node-seconds the job held (integral of its allocation over time).
    pub node_seconds: f64,
}

impl JobRecord {
    pub fn wait(&self) -> f64 {
        self.start - self.submit
    }
    pub fn exec(&self) -> f64 {
        self.end - self.start
    }
    pub fn completion(&self) -> f64 {
        self.end - self.submit
    }
}

/// Extract user-job records (resizers excluded), sorted by submission.
pub fn extract(rms: &Rms) -> Vec<JobRecord> {
    let mut out: Vec<JobRecord> = rms
        .jobs()
        .filter(|j| !j.is_resizer && j.start_time.is_some() && j.end_time.is_some())
        .map(|j| {
            let start = j.start_time.unwrap();
            let end = j.end_time.unwrap();
            // Integrate the allocation over the resize history.
            let mut t = start;
            let mut procs = j.spec.procs as f64;
            let mut node_seconds = 0.0;
            for r in &j.resize_log {
                node_seconds += procs * (r.time - t);
                t = r.time;
                procs = r.to_procs as f64;
            }
            node_seconds += procs * (end - t);
            JobRecord {
                name: j.spec.name.clone(),
                app: j.spec.app,
                submit: j.submit_time,
                start,
                end,
                initial_procs: j.spec.procs,
                n_expands: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs > r.from_procs)
                    .count(),
                n_shrinks: j
                    .resize_log
                    .iter()
                    .filter(|r| r.to_procs < r.from_procs)
                    .count(),
                node_seconds,
            }
        })
        .collect();
    out.sort_by(|a, b| a.submit.partial_cmp(&b.submit).unwrap().then(a.name.cmp(&b.name)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rms::{DmrRequest, RmsConfig};
    use crate::workload::JobSpec;

    #[test]
    fn extract_computes_node_seconds_across_resizes() {
        let mut rms = Rms::new(RmsConfig { nodes: 64, ..Default::default() });
        let spec = JobSpec::from_app(AppKind::Cg, "CG-0".into(), 0.0, 1.0);
        let a = rms.submit(spec, 0.0);
        rms.schedule(0.0); // 32 nodes
        // queue a job so the policy shrinks
        let waiting = JobSpec::from_app(AppKind::Cg, "CG-1".into(), 1.0, 1.0);
        rms.submit(waiting, 1.0);
        let req = DmrRequest { min: 2, max: 32, pref: Some(8), factor: 2 };
        let out = rms.dmr_check(a, &req, 10.0);
        assert!(matches!(out, crate::rms::DmrOutcome::Shrink { .. }));
        rms.commit_shrink_to(a, 8, 10.0);
        rms.finish(a, 20.0);

        let recs = extract(&rms);
        let r = recs.iter().find(|r| r.name == "CG-0").unwrap();
        assert_eq!(r.n_shrinks, 1);
        // 32 procs for 10 s + 8 procs for 10 s
        assert!((r.node_seconds - (320.0 + 80.0)).abs() < 1e-9);
        assert_eq!(r.wait(), 0.0);
        assert_eq!(r.exec(), 20.0);
    }
}
