//! Fig. 3 reproduction: the isolated reconfiguration-overhead study
//! (§7.3), measured live on this stack — RMS decision times and real
//! data-redistribution times across factor-2 reconfigurations 1↔2 … 32↔64.
//!
//! Payload defaults to 256 MB (the paper moves 1 GB over InfiniBand; set
//! `--mb 1024` to match).  Run:
//!     cargo run --release --example overhead_study -- --mb 1024 --reps 10

use dmr::live::overhead::fig3_sweep;
use dmr::util::cli::Args;
use dmr::util::csv::write_csv;
use dmr::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mb = args.get_parse("mb", 256usize);
    let reps = args.get_parse("reps", 5usize);
    println!("Fig 3 overhead study: {mb} MB payload, {reps} reps per point\n");

    let t0 = std::time::Instant::now();
    let samples = fig3_sweep(reps, mb * 1024 * 1024 / 4);

    let mut t = Table::new(vec!["Reconfiguration", "Scheduling (ms)", "Resize (ms)", "GB/s"])
        .with_title("Fig 3: scheduling and resize times (live measurement)");
    let mut rows = Vec::new();
    for s in &samples {
        let gbps = (mb as f64 / 1024.0) / s.resize_secs;
        t.row(vec![
            format!("{:>2} -> {:<2}", s.from, s.to),
            format!("{:.3}", s.sched_secs * 1e3),
            format!("{:.1}", s.resize_secs * 1e3),
            format!("{gbps:.2}"),
        ]);
        rows.push(vec![
            s.from.to_string(),
            s.to.to_string(),
            format!("{:.6}", s.sched_secs),
            format!("{:.6}", s.resize_secs),
        ]);
    }
    println!("{}", t.render());
    println!("total wall time: {:.1?}", t0.elapsed());

    // Paper-shape checks (§7.3):
    // (1) more processes involved => shorter resize (1->2 vs 32->64)
    let t_1_2 = samples.iter().find(|s| s.from == 1 && s.to == 2).unwrap().resize_secs;
    let t_32_64 = samples.iter().find(|s| s.from == 32 && s.to == 64).unwrap().resize_secs;
    println!("shape check: resize(1->2) = {:.0} ms  >  resize(32->64) = {:.0} ms : {}",
        t_1_2 * 1e3, t_32_64 * 1e3, if t_1_2 > t_32_64 { "OK" } else { "MISMATCH" });
    // (2) shrinks cost at least as much as the mirror expansions
    let exp: f64 = samples.iter().filter(|s| s.to > s.from).map(|s| s.resize_secs).sum();
    let shr: f64 = samples.iter().filter(|s| s.to < s.from).map(|s| s.resize_secs).sum();
    println!("shape check: total shrink {:.0} ms vs total expand {:.0} ms : {}",
        shr * 1e3, exp * 1e3, if shr > exp * 0.8 { "OK" } else { "MISMATCH" });

    write_csv("results/fig3_overhead_live.csv", &["from", "to", "sched_s", "resize_s"], &rows)?;
    println!("wrote results/fig3_overhead_live.csv");
    Ok(())
}
