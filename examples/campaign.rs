//! Campaign engine quickstart: load a declarative spec, sweep the
//! scenario matrix across worker threads, print the per-scenario
//! aggregates and write the CSV/JSON artifacts.
//!
//! ```sh
//! cargo run --release --example campaign
//! cargo run --release --example campaign -- scenarios/swf_replay.toml --workers 4
//! ```

use dmr::campaign::{self, CampaignSpec};
use dmr::metrics::report;
use dmr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let path = args
        .subcommand
        .clone()
        .unwrap_or_else(|| "scenarios/sweep_small.toml".to_string());
    let workers = args.get_parse("workers", 0usize);

    let spec = CampaignSpec::from_file(&path)?;
    println!(
        "campaign {}: {} runs on {} workers",
        spec.name,
        spec.matrix_size(),
        campaign::runner::resolve_workers(&spec, workers)
    );

    let result = campaign::run_campaign(&spec, workers)?;
    let aggs = campaign::aggregate(&result.records);
    println!("{}", report::campaign_table(&spec.name, &aggs).render());

    let out = campaign::write_outputs(&spec, &result)?;
    println!(
        "{} runs in {:.2}s ({:.1} runs/s)",
        result.records.len(),
        result.wall_secs,
        result.runs_per_sec()
    );
    println!("wrote {}", out.runs_csv.display());
    println!("wrote {}", out.agg_csv.display());
    println!("wrote {}", out.agg_json.display());
    Ok(())
}
