//! Quickstart: the malleability framework in ~40 lines.
//!
//! Generates a Feitelson workload (§7.1), processes it twice through the
//! discrete-event engine — once rigid ("fixed"), once malleable
//! ("flexible") — and prints the productivity gains the paper's Fig. 4/5
//! report.  No AOT artifacts required.
//!
//! Run: `cargo run --release --example quickstart`

use dmr::des::{DesConfig, Engine};
use dmr::metrics::RunSummary;
use dmr::util::stats::gain_pct;
use dmr::workload;

fn main() {
    // 1. A 50-job workload: CG / Jacobi / N-body jobs, Poisson arrivals.
    let wl = workload::generate(50, 42);
    println!("workload: {} jobs, seed {}", wl.len(), wl.seed);

    // 2. The rigid baseline: same job stream, malleability off.
    let fixed = Engine::new(DesConfig::default()).run(&wl.as_fixed(), "Fixed");

    // 3. The flexible version: jobs expose reconfiguring points; the RMS
    //    expands/shrinks them per the paper's §4 policy.
    let flex = Engine::new(DesConfig::default()).run(&wl, "Flexible");

    let f = RunSummary::from_run(fixed);
    let x = RunSummary::from_run(flex);

    println!("\n              {:>12} {:>12}", "fixed", "flexible");
    println!("makespan      {:>11.0}s {:>11.0}s  (gain {:.1}%)",
        f.makespan, x.makespan, gain_pct(f.makespan, x.makespan));
    println!("avg wait      {:>11.0}s {:>11.0}s  (gain {:.1}%)",
        f.wait.mean(), x.wait.mean(), gain_pct(f.wait.mean(), x.wait.mean()));
    println!("avg exec      {:>11.0}s {:>11.0}s  (jobs run shrunk: slower alone, faster together)",
        f.exec.mean(), x.exec.mean());
    println!("utilization   {:>11.1}% {:>11.1}%  (allocated-node fraction)",
        f.util_mean * 100.0, x.util_mean * 100.0);
    println!("node-seconds  {:>11.2e} {:>11.2e}  (smarter sizes burn fewer node-seconds)",
        f.node_seconds(), x.node_seconds());
    println!("\nreconfigurations: {} expansions, {} shrinks",
        x.actions.expand.count(), x.actions.shrink.count());

    assert!(x.makespan < f.makespan, "malleability should win");
    println!("\nquickstart OK");
}
