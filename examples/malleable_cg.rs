//! A malleable Conjugate Gradient solved live: real rank threads, real
//! PJRT compute (the AOT Pallas kernels), real data redistribution.
//!
//! The job starts at 2 processes with the queue empty, so the §4.2 policy
//! expands it toward its maximum (8); a later FS job queues, pressuring
//! the RMS to shrink CG back toward its preferred size.  The solution is
//! verified against an f64 reference solver at the end.
//!
//! Requires `make artifacts`.  Run:
//!     cargo run --release --example malleable_cg

use std::sync::mpsc;

use dmr::apps::config::AppKind;
use dmr::live::{LiveDriver, LiveOpts};
use dmr::rms::RmsConfig;
use dmr::runtime::ComputeServer;
use dmr::workload::JobSpec;

fn cg_ref(n: usize, iters: u32) -> Vec<f64> {
    let b: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.01).sin()).collect();
    let matvec = |v: &[f64]| -> Vec<f64> {
        (0..n)
            .map(|i| {
                let l = if i > 0 { v[i - 1] } else { 0.0 };
                let r = if i + 1 < n { v[i + 1] } else { 0.0 };
                2.0 * v[i] - l - r
            })
            .collect()
    };
    let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b);
    let mut rr: f64 = r.iter().map(|v| v * v).sum();
    for _ in 0..iters {
        let q = matvec(&p);
        let alpha = rr / p.iter().zip(&q).map(|(a, b)| a * b).sum::<f64>();
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * q[i];
        }
        let rr2: f64 = r.iter().map(|v| v * v).sum();
        let beta = rr2 / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr2;
    }
    x
}

fn main() -> anyhow::Result<()> {
    let server = ComputeServer::start_default()?;
    let (probe_tx, probe_rx) = mpsc::channel();
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 8, ..Default::default() },
        probe: Some(probe_tx),
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());

    let iters = 40;
    let mut cg = JobSpec::from_app(AppKind::Cg, "CG-demo".into(), 0.0, 1.0);
    cg.iterations = iters;
    cg.procs = 2;
    cg.min_procs = 2;
    cg.max_procs = 8;
    cg.pref_procs = Some(2);
    cg.sched_period = 0.0; // check every iteration for the demo

    // Queue pressure arrives mid-run: a rigid FS job wanting 4 nodes.
    std::env::set_var("DMR_TIME_SCALE", "0.001");
    let mut fs = JobSpec::from_app(AppKind::FlexibleSleep, "FS-pressure".into(), 0.08, 0.05);
    fs.iterations = 3;
    fs.procs = 4;
    fs.min_procs = 4;
    fs.max_procs = 4;
    fs.malleable = false;

    println!("running malleable CG (n=16384, {iters} iterations) ...");
    let t0 = std::time::Instant::now();
    let report = driver.run(vec![cg, fs]);
    println!("completed {} jobs in {:.2?}", report.jobs, t0.elapsed());

    {
        let rms = report.rms.lock().unwrap();
        let job = rms
            .jobs()
            .find(|j| j.spec.name == "CG-demo")
            .expect("CG job record");
        println!("resize history of CG-demo:");
        for r in &job.resize_log {
            let kind = if r.to_procs > r.from_procs { "EXPAND" } else { "SHRINK" };
            println!("  t={:>6.2}s  {kind}  {} -> {} processes", r.time, r.from_procs, r.to_procs);
        }
        println!(
            "RMS log: {} expansions, {} shrinks",
            rms.log.expansions(),
            rms.log.shrinks()
        );
    }

    // Verify the solution survived the resizes.
    let want = cg_ref(16384, iters);
    let mut checked = false;
    while let Ok((_, sol)) = probe_rx.try_recv() {
        if sol.len() == 16384 {
            let num: f64 = sol
                .iter()
                .zip(&want)
                .map(|(g, w)| (*g as f64 - w) * (*g as f64 - w))
                .sum::<f64>()
                .sqrt();
            let den: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
            let rel = num / den;
            println!("solution rel. error vs f64 reference: {rel:.2e}");
            assert!(rel < 1e-3, "solution diverged");
            checked = true;
        }
    }
    assert!(checked, "no CG solution probe received");
    println!("malleable_cg OK");
    Ok(())
}
