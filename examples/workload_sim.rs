//! End-to-end driver: the full system on a real small workload.
//!
//! Phase 1 (live): an 8-job adaptive workload runs through the complete
//! stack — Feitelson generator → RMS (priorities, backfill, §4 policy) →
//! DMR runtime (spawn + redistribution over vmpi) → PJRT compute (the AOT
//! Pallas kernels) — with real threads and real bytes.  Fixed vs flexible
//! on the same stream; the headline metric (workload completion time) is
//! reported like Fig. 4.
//!
//! Phase 2 (DES): the paper-scale 50-job version of the same comparison
//! in virtual time.
//!
//! Requires `make artifacts`.  Run:
//!     cargo run --release --example workload_sim

use dmr::des::{DesConfig, Engine};
use dmr::live::{LiveDriver, LiveOpts};
use dmr::metrics::RunSummary;
use dmr::rms::RmsConfig;
use dmr::runtime::ComputeServer;
use dmr::util::stats::gain_pct;
use dmr::workload;

fn live_specs(flexible: bool) -> Vec<dmr::workload::JobSpec> {
    let mut w = workload::generate(8, 7);
    w.jobs
        .drain(..)
        .enumerate()
        .map(|(i, mut s)| {
            // Scale the workload to live size: few iterations, small
            // process counts (within the artifact set), fast arrivals.
            s.iterations = match s.app {
                dmr::apps::config::AppKind::NBody => 6,
                _ => 10,
            };
            s.procs = if i % 2 == 0 { 8 } else { 4 };
            s.max_procs = 8;
            s.min_procs = 2;
            s.pref_procs = Some(2);
            s.sched_period = 0.0;
            s.malleable = flexible;
            s
        })
        .collect()
}

struct RunSummaryLite {
    jobs: usize,
    avg_wait: f64,
    avg_exec: f64,
    expansions: usize,
    shrinks: usize,
}

fn run_live(server: &ComputeServer, flexible: bool) -> (f64, RunSummaryLite) {
    let opts = LiveOpts {
        rms: RmsConfig { nodes: 16, ..Default::default() },
        arrival_scale: 0.02,
        ..Default::default()
    };
    let mut driver = LiveDriver::new(opts, server.handle());
    let t0 = std::time::Instant::now();
    let report = driver.run(live_specs(flexible));
    let makespan = t0.elapsed().as_secs_f64();
    let rms = report.rms.lock().unwrap();
    let jobs = dmr::metrics::extract(&rms);
    let lite = RunSummaryLite {
        jobs: jobs.len(),
        avg_wait: jobs.iter().map(|j| j.wait()).sum::<f64>() / jobs.len() as f64,
        avg_exec: jobs.iter().map(|j| j.exec()).sum::<f64>() / jobs.len() as f64,
        expansions: rms.log.expansions(),
        shrinks: rms.log.shrinks(),
    };
    (makespan, lite)
}

fn main() -> anyhow::Result<()> {
    // ---------------- Phase 1: live, real compute -----------------
    println!("=== Phase 1: live 8-job workload (real PJRT compute) ===");
    let server = ComputeServer::start_default()?;

    let (t_fixed, s_fixed) = run_live(&server, false);
    println!(
        "fixed   : {} jobs in {:.2}s (wait {:.2}s, exec {:.2}s)",
        s_fixed.jobs, t_fixed, s_fixed.avg_wait, s_fixed.avg_exec
    );
    let (t_flex, s_flex) = run_live(&server, true);
    println!(
        "flexible: {} jobs in {:.2}s (wait {:.2}s, exec {:.2}s, {} expands, {} shrinks)",
        s_flex.jobs, t_flex, s_flex.avg_wait, s_flex.avg_exec, s_flex.expansions, s_flex.shrinks
    );
    println!(
        "live workload completion gain: {:.1}% (paper Fig. 4 reports 52-63% at cluster scale)",
        gain_pct(t_fixed, t_flex)
    );

    // PJRT executor statistics prove compute ran through the artifacts.
    let stats = server.handle().stats();
    let total_calls: u64 = stats.iter().map(|s| s.calls).sum();
    println!("PJRT executions: {total_calls} artifact calls across {} executables", stats.len());
    assert!(total_calls > 0, "no PJRT compute happened");

    // ---------------- Phase 2: paper-scale DES -----------------
    println!("\n=== Phase 2: DES 50-job workload (paper scale, virtual time) ===");
    let wl = workload::generate(50, 42);
    let fixed =
        RunSummary::from_run(Engine::new(DesConfig::default()).run(&wl.as_fixed(), "Fixed"));
    let flex = RunSummary::from_run(Engine::new(DesConfig::default()).run(&wl, "Flexible"));
    println!(
        "fixed   : makespan {:>8.0}s  util {:>5.1}%  wait {:>7.0}s  exec {:>5.0}s",
        fixed.makespan, fixed.util_mean * 100.0, fixed.wait.mean(), fixed.exec.mean()
    );
    println!(
        "flexible: makespan {:>8.0}s  util {:>5.1}%  wait {:>7.0}s  exec {:>5.0}s",
        flex.makespan, flex.util_mean * 100.0, flex.wait.mean(), flex.exec.mean()
    );
    println!(
        "DES completion gain: {:.1}%  (paper: 52.3% for 50 jobs)",
        gain_pct(fixed.makespan, flex.makespan)
    );
    assert!(flex.makespan < fixed.makespan);
    println!("\nworkload_sim OK");
    Ok(())
}
