"""L2: per-application JAX step functions, each calling the L1 Pallas kernels.

These are the computations the malleable applications execute on every
iteration, written against the *local shard* a rank owns plus the halo /
gathered data the Rust vmpi layer supplies.  Global reductions (CG dot
products, Jacobi residual, N-body energy) are returned as *partial* scalars;
the Rust coordinator allreduces them across ranks.

Every function here is lowered per (app, nprocs) shard shape by aot.py and
executed from Rust via PJRT — Python never runs on the request path.

Problem sizes (global, fixed; shard = global / nprocs):
    CG      vector length  N_CG     = 16384
    Jacobi  grid           512 x 256 rows x cols (row-sharded)
    N-body  bodies         N_NB     = 1024
"""

import jax
import jax.numpy as jnp

from .kernels import jacobi_sweep, laplacian_matvec, nbody_accel

# Global problem sizes.  Divisible by every supported process count (1..32).
N_CG = 16384
JACOBI_ROWS = 512
JACOBI_COLS = 256
N_NB = 1024

#: Process counts artifacts are generated for (powers of two; the paper's
#: resize factor is 2, so every reachable configuration is a power of two).
PROC_COUNTS = (1, 2, 4, 8, 16, 32)


# ---------------------------------------------------------------------------
# Conjugate Gradient.  Split into three phases around the two global
# reductions (alpha needs p.q, beta needs r'.r'); the Rust side allreduces
# between phases.


def cg_phase1(p_loc, halo_l, halo_r):
    """q = A p (local block row) and the local partial of p.q.

    halo_l / halo_r are (1,) arrays holding the neighbour boundary values
    (zero at the domain ends).
    """
    xp = jnp.concatenate([halo_l, p_loc, halo_r])
    q = laplacian_matvec(xp)
    partial_pq = jnp.dot(p_loc, q)
    return q, partial_pq.reshape(1)


def cg_phase2(x_loc, r_loc, p_loc, q_loc, alpha):
    """x += alpha p;  r -= alpha q;  partial of r'.r'.  alpha is (1,)."""
    a = alpha[0]
    x2 = x_loc + a * p_loc
    r2 = r_loc - a * q_loc
    partial_rr = jnp.dot(r2, r2)
    return x2, r2, partial_rr.reshape(1)


def cg_phase3(r_loc, p_loc, beta):
    """p = r + beta p.  beta is (1,)."""
    return (r_loc + beta[0] * p_loc,)


def cg_shapes(nprocs: int):
    n = N_CG // nprocs
    f32 = jnp.float32
    v = jax.ShapeDtypeStruct((n,), f32)
    s = jax.ShapeDtypeStruct((1,), f32)
    return {
        "cg_phase1": (v, s, s),
        "cg_phase2": (v, v, v, v, s),
        "cg_phase3": (v, v, s),
    }


# ---------------------------------------------------------------------------
# Jacobi.  One sweep over the rank's row block; halo rows from neighbours.


def jacobi_step(u_loc, halo_top, halo_bot, b_loc):
    """One 5-point sweep.  u_loc (rows, cols); halos (1, cols).

    Returns the updated block and the local partial of the squared update
    norm  sum((u' - u)^2)  used as the convergence measure.
    """
    rows, cols = u_loc.shape
    inner = jnp.concatenate([halo_top, u_loc, halo_bot], axis=0)
    up = jnp.pad(inner, ((0, 0), (1, 1)))  # Dirichlet zero side columns
    u2 = jacobi_sweep(up, b_loc)
    diff = u2 - u_loc
    partial = jnp.sum(diff * diff)
    return u2, partial.reshape(1)


def jacobi_shapes(nprocs: int):
    rows = JACOBI_ROWS // nprocs
    f32 = jnp.float32
    blk = jax.ShapeDtypeStruct((rows, JACOBI_COLS), f32)
    halo = jax.ShapeDtypeStruct((1, JACOBI_COLS), f32)
    return {"jacobi_step": (blk, halo, halo, blk)}


# ---------------------------------------------------------------------------
# N-body.  Symplectic-Euler step of the local shard against all bodies
# (positions all-gathered by the coordinator between steps).


def nbody_step(pos_all, pos_loc, vel_loc, mass_all, dt):
    """Returns (pos_loc', vel_loc', partial kinetic energy).  dt is (1,)."""
    acc = nbody_accel(pos_all, pos_loc, mass_all)
    v2 = vel_loc + dt[0] * acc
    p2 = pos_loc + dt[0] * v2
    ke = 0.5 * jnp.sum(v2 * v2)
    return p2, v2, ke.reshape(1)


def nbody_shapes(nprocs: int):
    n = N_NB // nprocs
    f32 = jnp.float32
    return {
        "nbody_step": (
            jax.ShapeDtypeStruct((N_NB, 3), f32),
            jax.ShapeDtypeStruct((n, 3), f32),
            jax.ShapeDtypeStruct((n, 3), f32),
            jax.ShapeDtypeStruct((N_NB,), f32),
            jax.ShapeDtypeStruct((1,), f32),
        )
    }


FUNCTIONS = {
    "cg_phase1": cg_phase1,
    "cg_phase2": cg_phase2,
    "cg_phase3": cg_phase3,
    "jacobi_step": jacobi_step,
    "nbody_step": nbody_step,
}


def all_variants():
    """Yield (artifact_name, fn, example_shapes) for every (fn, nprocs)."""
    for p in PROC_COUNTS:
        shapes = {}
        shapes.update(cg_shapes(p))
        shapes.update(jacobi_shapes(p))
        shapes.update(nbody_shapes(p))
        for name, args in shapes.items():
            yield f"{name}_p{p}", FUNCTIONS[name], args
