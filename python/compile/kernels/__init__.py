# L1: Pallas kernels for the compute hot-spots of the three malleable
# applications the paper evaluates (CG, Jacobi, N-body).
#
# All kernels are lowered with interpret=True: the CPU PJRT plugin cannot
# execute Mosaic custom-calls, and the paper's applications are CPU-cluster
# MPI codes anyway.  The kernels are still *structured* for TPU execution:
# block-tiled via BlockSpec/grid so the HBM<->VMEM schedule is explicit (see
# DESIGN.md "Hardware adaptation").
from .cg import laplacian_matvec
from .jacobi import jacobi_sweep
from .nbody import nbody_accel

__all__ = ["laplacian_matvec", "jacobi_sweep", "nbody_accel"]
