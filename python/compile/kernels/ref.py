"""Pure-jnp oracles for the Pallas kernels (the build-time correctness gate).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only; pytest (python/tests/) asserts allclose
between kernel and oracle across a hypothesis-driven shape/dtype sweep.
"""

import jax
import jax.numpy as jnp

EPS = 1e-6  # must match kernels.nbody.EPS


def laplacian_matvec_ref(xp: jax.Array) -> jax.Array:
    """y = tridiag(-1, 2, -1) @ x for padded input xp of shape (n+2,)."""
    return 2.0 * xp[1:-1] - xp[:-2] - xp[2:]


def jacobi_sweep_ref(up: jax.Array, b: jax.Array) -> jax.Array:
    """One 5-point Jacobi sweep over padded (rows+2, cols+2) input."""
    north = up[:-2, 1:-1]
    south = up[2:, 1:-1]
    west = up[1:-1, :-2]
    east = up[1:-1, 2:]
    return 0.25 * (north + south + west + east - b)


def nbody_accel_ref(pos_all: jax.Array, pos_loc: jax.Array, mass_all: jax.Array) -> jax.Array:
    """acc[i] = sum_j m[j] (p[j]-p[i]) / (|p[j]-p[i]|^2 + eps)^1.5."""
    d = pos_all[None, :, :] - pos_loc[:, None, :]  # (n, N, 3)
    r2 = jnp.sum(d * d, axis=-1) + EPS
    w = mass_all[None, :] * r2 ** (-1.5)
    return jnp.sum(w[..., None] * d, axis=1)


# ---------------------------------------------------------------------------
# Whole-algorithm references (used by integration tests to validate the
# distributed Rust execution end-to-end).


def cg_solve_ref(b: jax.Array, iters: int) -> jax.Array:
    """`iters` steps of CG on tridiag(-1,2,-1) x = b, single domain."""

    def matvec(x):
        xp = jnp.pad(x, 1)
        return laplacian_matvec_ref(xp)

    x = jnp.zeros_like(b)
    r = b - matvec(x)
    p = r
    rr = jnp.dot(r, r)
    for _ in range(iters):
        q = matvec(p)
        alpha = rr / jnp.dot(p, q)
        x = x + alpha * p
        r = r - alpha * q
        rr_new = jnp.dot(r, r)
        beta = rr_new / rr
        p = r + beta * p
        rr = rr_new
    return x


def jacobi_solve_ref(b: jax.Array, iters: int) -> jax.Array:
    """`iters` Jacobi sweeps on the 2-D Poisson problem, zero boundary."""
    u = jnp.zeros_like(b)
    for _ in range(iters):
        up = jnp.pad(u, 1)
        u = jacobi_sweep_ref(up, b)
    return u


def nbody_step_ref(pos, vel, mass, dt):
    """One symplectic-Euler step over the full body set."""
    acc = nbody_accel_ref(pos, pos, mass)
    vel = vel + dt * acc
    pos = pos + dt * vel
    return pos, vel
