"""Pallas kernel for the Jacobi hot-spot: 2-D 5-point stencil sweep.

The distributed Jacobi solver shards the grid by contiguous *row blocks*;
each rank's sweep needs one halo row from each neighbour.  The kernel
consumes the *padded* local block ``up`` of shape ``(rows+2, cols+2)``
(halo rows exchanged by the Rust vmpi layer; halo columns are the Dirichlet
boundary, zero) plus the local right-hand side ``b`` and produces

    u'[r,c] = 0.25 * (up[r,c+1] + up[r+2,c+1] + up[r+1,c] + up[r+1,c+2]
                      - b[r,c])

TPU mapping: the output is tiled into (block_r, cols) VMEM stripes; each
grid step loads four shifted windows of the padded input.  ``cols`` is kept
a multiple of 128 (lane width) in the shipped configurations so the loads
are lane-aligned.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(up_ref, b_ref, out_ref):
    i = pl.program_id(0)
    br, c = out_ref.shape
    r0 = i * br
    north = pl.load(up_ref, (pl.dslice(r0, br), pl.dslice(1, c)))
    south = pl.load(up_ref, (pl.dslice(r0 + 2, br), pl.dslice(1, c)))
    west = pl.load(up_ref, (pl.dslice(r0 + 1, br), pl.dslice(0, c)))
    east = pl.load(up_ref, (pl.dslice(r0 + 1, br), pl.dslice(2, c)))
    out_ref[...] = 0.25 * (north + south + west + east - b_ref[...])


def _pick_block(n: int, target: int = 64) -> int:
    best = 1
    for b in range(1, min(n, target) + 1):
        if n % b == 0:
            best = b
    return best


@functools.partial(jax.jit, static_argnames=("block_r",))
def jacobi_sweep(up: jax.Array, b: jax.Array, block_r: int | None = None) -> jax.Array:
    """One Jacobi sweep over the padded local block ``up`` (rows+2, cols+2)."""
    rows, cols = b.shape
    assert up.shape == (rows + 2, cols + 2), (up.shape, b.shape)
    if block_r is None:
        block_r = _pick_block(rows)
    assert rows % block_r == 0, f"block_r {block_r} must divide rows {rows}"
    grid = (rows // block_r,)
    return pl.pallas_call(
        _jacobi_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(up.shape, lambda i: (0, 0)),
            pl.BlockSpec((block_r, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), up.dtype),
        interpret=True,
    )(up, b)
