"""Pallas kernel for the N-body hot-spot: all-pairs gravitational forces.

The distributed N-body app shards the bodies across ranks; positions are
all-gathered (by the Rust vmpi layer) each step, so each rank computes the
acceleration of its *local* bodies against *all* bodies:

    acc[i] = sum_j  m[j] * (p[j] - p_loc[i]) / (|p[j] - p_loc[i]|^2 + eps)^1.5

TPU mapping: a 2-D grid tiles the local bodies (i) and the interaction
partners (j).  Each grid step materializes a (TILE_I, TILE_J, 3) interaction
block in VMEM and accumulates into the i-tile of the output — the Pallas
revisiting-output accumulation pattern.  The (TILE_I, TILE_J) distance matrix
is the MXU-shaped inner product; with bf16 inputs this maps onto the systolic
array on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _nbody_kernel(pall_ref, ploc_ref, m_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pj = pall_ref[...]  # (tj, 3)
    pi = ploc_ref[...]  # (ti, 3)
    d = pj[None, :, :] - pi[:, None, :]  # (ti, tj, 3)
    r2 = jnp.sum(d * d, axis=-1) + EPS  # (ti, tj)
    inv_r = jax.lax.rsqrt(r2)
    w = m_ref[...][None, :] * inv_r * inv_r * inv_r  # (ti, tj)
    acc_ref[...] += jnp.sum(w[..., None] * d, axis=1)


def _pick_tile(n: int, target: int) -> int:
    best = 1
    for b in range(1, min(n, target) + 1):
        if n % b == 0:
            best = b
    return best


@functools.partial(jax.jit, static_argnames=("tile_i", "tile_j"))
def nbody_accel(
    pos_all: jax.Array,
    pos_loc: jax.Array,
    mass_all: jax.Array,
    tile_i: int | None = None,
    tile_j: int | None = None,
) -> jax.Array:
    """Accelerations of local bodies against all bodies. Shapes (N,3),(n,3),(N,)."""
    n_all = pos_all.shape[0]
    n_loc = pos_loc.shape[0]
    if tile_i is None:
        tile_i = _pick_tile(n_loc, 64)
    if tile_j is None:
        tile_j = _pick_tile(n_all, 128)
    assert n_loc % tile_i == 0 and n_all % tile_j == 0
    grid = (n_loc // tile_i, n_all // tile_j)
    return pl.pallas_call(
        _nbody_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_j, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_i, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_j,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_i, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_loc, 3), pos_loc.dtype),
        interpret=True,
    )(pos_all, pos_loc, mass_all)
