"""Pallas kernel for the CG hot-spot: 1-D Laplacian (tridiagonal) matvec.

The distributed CG solver shards the vector across ranks; each rank's matvec
needs one halo element from each neighbour.  The kernel therefore consumes a
*padded* local vector ``xp`` of length ``n + 2`` (``xp[0]`` / ``xp[n+1]`` are
the halo values, exchanged by the Rust vmpi layer) and produces

    y[i] = 2*xp[i+1] - xp[i] - xp[i+2]        (i.e. y = A_local x)

which is the local block-row of ``A = tridiag(-1, 2, -1)``.

TPU mapping: the output is tiled into VMEM blocks of ``block`` elements; the
padded input is resident (ANY memory space) and each grid step loads three
shifted windows — on real TPU hardware this becomes an HBM->VMEM streamed
sweep with a 2-element overlap, the classic stencil double-buffer schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(xp_ref, y_ref):
    i = pl.program_id(0)
    blk = y_ref.shape[0]
    start = i * blk
    left = pl.load(xp_ref, (pl.dslice(start, blk),))
    center = pl.load(xp_ref, (pl.dslice(start + 1, blk),))
    right = pl.load(xp_ref, (pl.dslice(start + 2, blk),))
    y_ref[...] = 2.0 * center - left - right


def _pick_block(n: int, target: int = 256) -> int:
    """Largest divisor of ``n`` that is <= target (VMEM-friendly tile)."""
    best = 1
    for b in range(1, min(n, target) + 1):
        if n % b == 0:
            best = b
    return best


@functools.partial(jax.jit, static_argnames=("block",))
def laplacian_matvec(xp: jax.Array, block: int | None = None) -> jax.Array:
    """y = tridiag(-1,2,-1) @ x for the padded local shard ``xp`` (n+2,)."""
    n = xp.shape[0] - 2
    if block is None:
        block = _pick_block(n)
    assert n % block == 0, f"block {block} must divide n {n}"
    grid = (n // block,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), xp.dtype),
        interpret=True,
    )(xp)
