"""AOT pipeline: lower every (app step, nprocs) variant to HLO *text*.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the Rust side reassigns ids and round-trips cleanly.

Run once via ``make artifacts``; the Rust binary is self-contained after.

Outputs (under --out, default ../artifacts):
    <fn>_p<P>.hlo.txt   one per variant
    manifest.json       name -> {inputs: [[shape], dtype], outputs: [...]}
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def spec_list(avals):
    out = []
    for a in avals:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    n = 0
    for name, fn, example_args in model.all_variants():
        if args.only and args.only not in name:
            continue
        lowered = lower_variant(fn, example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        manifest[name] = {
            "inputs": spec_list(example_args),
            "outputs": spec_list(out_avals),
        }
        n += 1
        print(f"[aot] {name}: {len(text)} chars", file=sys.stderr)

    man_path = os.path.join(args.out, "manifest.json")
    # Merge with any existing manifest so --only refreshes incrementally.
    if os.path.exists(man_path) and args.only:
        with open(man_path) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {n} artifacts + manifest to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
