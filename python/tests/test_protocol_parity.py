"""Cross-language parity checks: the Rust runtime mirrors the constants
and shard layouts defined here (model.py is the source of truth for the
AOT shapes; rust/src/apps/state.rs mirrors them).

These tests parse the Rust sources so a drift between the layers fails the
Python suite at build time, before any artifact mismatch can reach PJRT.
"""

import os
import re

from compile import model

RUST_STATE = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "src", "apps", "state.rs"
)


def _rust_const(name: str) -> int:
    src = open(RUST_STATE).read()
    m = re.search(rf"pub const {name}: usize = (\d+);", src)
    assert m, f"constant {name} not found in state.rs"
    return int(m.group(1))


def test_problem_sizes_match_rust():
    assert _rust_const("N_CG") == model.N_CG
    assert _rust_const("JACOBI_ROWS") == model.JACOBI_ROWS
    assert _rust_const("JACOBI_COLS") == model.JACOBI_COLS
    assert _rust_const("N_NB") == model.N_NB


def test_proc_counts_match_rust():
    src = open(RUST_STATE).read()
    m = re.search(r"pub const PROC_COUNTS: \[usize; (\d+)\] = \[([0-9, ]+)\];", src)
    assert m, "PROC_COUNTS not found"
    rust_counts = tuple(int(x) for x in m.group(2).split(","))
    assert rust_counts == tuple(model.PROC_COUNTS)


def test_jacobi_cols_lane_aligned():
    # The kernel docs promise lane-aligned loads (multiples of 128).
    assert model.JACOBI_COLS % 128 == 0


def test_every_artifact_shape_is_shardable_by_factor2():
    """Factor-2 resizes must keep shard shapes inside the artifact set."""
    for p in model.PROC_COUNTS:
        for q in model.PROC_COUNTS:
            if q == p * 2 or p == q * 2:
                # both sides exist -> redistribution between them is legal
                assert model.N_CG % p == 0 and model.N_CG % q == 0
