"""L2 model tests: the sharded step functions compose to the global algorithm.

These mirror exactly what the Rust coordinator does (halo exchange,
allreduce of partials, allgather of positions) so a pass here certifies the
numerical contract the runtime relies on.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _shard(x, p, i):
    n = x.shape[0] // p
    return x[i * n : (i + 1) * n]


@pytest.mark.parametrize("nprocs", [1, 2, 4])
def test_cg_phases_match_reference_solver(nprocs):
    """Run 5 distributed CG iterations via the three phases and compare
    against the single-domain reference solver."""
    n = 128
    rs = np.random.RandomState(0)
    b = jnp.asarray(rs.randn(n).astype(np.float32))

    iters = 5
    want = ref.cg_solve_ref(b, iters)

    # Distributed state per rank.
    x = [jnp.zeros((n // nprocs,), jnp.float32) for _ in range(nprocs)]
    r = [_shard(b, nprocs, i) for i in range(nprocs)]  # r0 = b - A*0 = b
    p = [ri for ri in r]
    rr = float(sum(float(jnp.dot(ri, ri)) for ri in r))

    def halos(vecs, i):
        hl = vecs[i - 1][-1:] if i > 0 else jnp.zeros((1,), jnp.float32)
        hr = vecs[i + 1][:1] if i < nprocs - 1 else jnp.zeros((1,), jnp.float32)
        return hl, hr

    for _ in range(iters):
        q, pq_parts = [], []
        for i in range(nprocs):
            hl, hr = halos(p, i)
            qi, pqi = model.cg_phase1(p[i], hl, hr)
            q.append(qi)
            pq_parts.append(float(pqi[0]))
        alpha = rr / sum(pq_parts)  # "allreduce"
        a = jnp.asarray([alpha], jnp.float32)
        rr_parts = []
        for i in range(nprocs):
            x[i], r[i], rri = model.cg_phase2(x[i], r[i], p[i], q[i], a)
            rr_parts.append(float(rri[0]))
        rr_new = sum(rr_parts)
        beta = jnp.asarray([rr_new / rr], jnp.float32)
        for i in range(nprocs):
            (p[i],) = model.cg_phase3(r[i], p[i], beta)
        rr = rr_new

    got = jnp.concatenate(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("nprocs", [1, 2, 4])
def test_jacobi_step_matches_global_sweep(nprocs):
    rows, cols = 32, 16
    rs = np.random.RandomState(1)
    u = jnp.asarray(rs.randn(rows, cols).astype(np.float32))
    b = jnp.asarray(rs.randn(rows, cols).astype(np.float32))

    up = jnp.pad(u, 1)
    want = ref.jacobi_sweep_ref(up, b)
    want_res = float(jnp.sum((want - u) ** 2))

    lr = rows // nprocs
    got_blocks, partials = [], []
    for i in range(nprocs):
        blk = u[i * lr : (i + 1) * lr]
        top = u[i * lr - 1 : i * lr] if i > 0 else jnp.zeros((1, cols), jnp.float32)
        bot = (
            u[(i + 1) * lr : (i + 1) * lr + 1]
            if i < nprocs - 1
            else jnp.zeros((1, cols), jnp.float32)
        )
        b_blk = b[i * lr : (i + 1) * lr]
        u2, res = model.jacobi_step(blk, top, bot, b_blk)
        got_blocks.append(u2)
        partials.append(float(res[0]))

    got = jnp.concatenate(got_blocks, axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert abs(sum(partials) - want_res) / max(want_res, 1e-9) < 1e-3


@pytest.mark.parametrize("nprocs", [1, 2, 4])
def test_nbody_step_matches_global_step(nprocs):
    n = 64
    rs = np.random.RandomState(2)
    pos = jnp.asarray(rs.randn(n, 3).astype(np.float32))
    vel = jnp.asarray(rs.randn(n, 3).astype(np.float32) * 0.1)
    mass = jnp.asarray(np.abs(rs.randn(n)).astype(np.float32) + 0.5)
    dt = jnp.asarray([1e-3], jnp.float32)

    want_pos, want_vel = ref.nbody_step_ref(pos, vel, mass, float(dt[0]))

    ln = n // nprocs
    got_pos, got_vel = [], []
    for i in range(nprocs):
        p2, v2, _ = model.nbody_step(
            pos, pos[i * ln : (i + 1) * ln], vel[i * ln : (i + 1) * ln], mass, dt
        )
        got_pos.append(p2)
        got_vel.append(v2)
    np.testing.assert_allclose(jnp.concatenate(got_pos), want_pos, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(jnp.concatenate(got_vel), want_vel, rtol=2e-3, atol=2e-3)


def test_all_variants_enumerates_every_proc_count():
    names = [name for name, _, _ in model.all_variants()]
    assert len(names) == len(set(names))
    for p in model.PROC_COUNTS:
        assert f"cg_phase1_p{p}" in names
        assert f"jacobi_step_p{p}" in names
        assert f"nbody_step_p{p}" in names
    # 5 functions x |PROC_COUNTS|
    assert len(names) == 5 * len(model.PROC_COUNTS)


def test_shard_shapes_divide_evenly():
    for p in model.PROC_COUNTS:
        assert model.N_CG % p == 0
        assert model.JACOBI_ROWS % p == 0
        assert model.N_NB % p == 0
