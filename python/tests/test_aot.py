"""AOT pipeline tests: HLO text artifacts + manifest integrity."""

import json
import os

import pytest

import jax
import jax.numpy as jnp

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable_header():
    lowered = aot.lower_variant(model.cg_phase3, model.cg_shapes(32)["cg_phase3"])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: root computation returns a tuple
    assert "tuple" in text.lower()


def test_spec_list():
    specs = aot.spec_list(
        [jax.ShapeDtypeStruct((4, 2), jnp.float32), jax.ShapeDtypeStruct((1,), jnp.float32)]
    )
    assert specs == [
        {"shape": [4, 2], "dtype": "float32"},
        {"shape": [1], "dtype": "float32"},
    ]


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_covers_all_variants():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, _, _ in model.all_variants():
        assert name in manifest, f"missing artifact entry {name}"
        assert os.path.exists(os.path.join(ART, f"{name}.hlo.txt"))


@needs_artifacts
def test_manifest_shapes_match_model():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, _, example_args in model.all_variants():
        want = [list(a.shape) for a in example_args]
        got = [s["shape"] for s in manifest[name]["inputs"]]
        assert got == want, name
