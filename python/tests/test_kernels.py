"""Kernel-vs-oracle correctness: the CORE build-time signal.

hypothesis sweeps shapes and block sizes; every Pallas kernel must match its
pure-jnp reference to float32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import laplacian_matvec, jacobi_sweep, nbody_accel
from compile.kernels import ref

RTOL = 1e-5
ATOL = 1e-5


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32))


# ---------------------------------------------------------------------------
# CG: Laplacian matvec


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 512), seed=st.integers(0, 2**31 - 1))
def test_matvec_matches_ref(n, seed):
    xp = _rand((n + 2,), seed)
    got = laplacian_matvec(xp)
    want = ref.laplacian_matvec_ref(xp)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n,block", [(16, 1), (16, 4), (16, 16), (256, 32), (96, 24)])
def test_matvec_block_sizes(n, block):
    xp = _rand((n + 2,), 7)
    got = laplacian_matvec(xp, block=block)
    want = ref.laplacian_matvec_ref(xp)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_matvec_is_tridiag_matrix():
    """Kernel equals the dense tridiag(-1,2,-1) matvec."""
    n = 32
    x = _rand((n,), 3)
    a = 2 * np.eye(n) - np.eye(n, k=1) - np.eye(n, k=-1)
    xp = jnp.pad(x, 1)
    np.testing.assert_allclose(
        laplacian_matvec(xp), (a @ np.asarray(x)).astype(np.float32), rtol=1e-4, atol=1e-4
    )


def test_matvec_halo_values_enter_boundary_rows():
    n = 8
    xp = jnp.zeros((n + 2,), jnp.float32).at[0].set(3.0).at[n + 1].set(5.0)
    y = np.asarray(laplacian_matvec(xp))
    assert y[0] == -3.0 and y[-1] == -5.0
    assert np.all(y[1:-1] == 0.0)


# ---------------------------------------------------------------------------
# Jacobi: 5-point sweep


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 96),
    cols=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_matches_ref(rows, cols, seed):
    up = _rand((rows + 2, cols + 2), seed)
    b = _rand((rows, cols), seed + 1)
    got = jacobi_sweep(up, b)
    want = ref.jacobi_sweep_ref(up, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("rows,block_r", [(8, 1), (8, 2), (8, 8), (64, 16)])
def test_jacobi_block_sizes(rows, block_r):
    up = _rand((rows + 2, 18), 11)
    b = _rand((rows, 16), 12)
    got = jacobi_sweep(up, b, block_r=block_r)
    want = ref.jacobi_sweep_ref(up, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_jacobi_fixed_point():
    """A harmonic (linear) field with b=0 is a fixed point of the sweep."""
    rows, cols = 16, 16
    # u(x,y) = x is harmonic; pad with its own boundary values.
    full = np.tile(np.arange(cols + 2, dtype=np.float32), (rows + 2, 1))
    up = jnp.asarray(full)
    b = jnp.zeros((rows, cols), jnp.float32)
    got = jacobi_sweep(up, b)
    np.testing.assert_allclose(got, full[1:-1, 1:-1], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# N-body: all-pairs accelerations


@settings(max_examples=20, deadline=None)
@given(
    n_all=st.integers(1, 96),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_nbody_matches_ref(n_all, frac, seed):
    n_loc = max(1, int(n_all * frac))
    rs = np.random.RandomState(seed % 2**31)
    pos_all = jnp.asarray(rs.randn(n_all, 3).astype(np.float32))
    pos_loc = pos_all[:n_loc]
    mass = jnp.asarray(np.abs(rs.randn(n_all)).astype(np.float32) + 0.1)
    got = nbody_accel(pos_all, pos_loc, mass)
    want = ref.nbody_accel_ref(pos_all, pos_loc, mass)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("ti,tj", [(1, 1), (4, 8), (16, 16), (16, 64)])
def test_nbody_tile_sizes(ti, tj):
    rs = np.random.RandomState(5)
    pos_all = jnp.asarray(rs.randn(64, 3).astype(np.float32))
    pos_loc = pos_all[:16]
    mass = jnp.asarray(np.abs(rs.randn(64)).astype(np.float32) + 0.1)
    got = nbody_accel(pos_all, pos_loc, mass, tile_i=ti, tile_j=tj)
    want = ref.nbody_accel_ref(pos_all, pos_loc, mass)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_nbody_momentum_conservation():
    """Total force over all bodies (equal masses) is ~zero."""
    rs = np.random.RandomState(9)
    pos = jnp.asarray(rs.randn(32, 3).astype(np.float32))
    mass = jnp.ones((32,), jnp.float32)
    acc = nbody_accel(pos, pos, mass)
    total = np.asarray(acc).sum(axis=0)
    np.testing.assert_allclose(total, np.zeros(3), atol=1e-3)
